//! Continuous (iteration-level) dynamic batcher.
//!
//! Orca/vLLM-style scheduling over a two-phase step: prefilling
//! sequences consume their prompts in batched chunks under a **shared
//! per-step prefill token budget** (`ServeConfig::prefill_budget`,
//! spread round-robin across prefilling slots — so decode stall per step
//! is bounded regardless of how many prompts are in flight, the
//! per-slot-cap gap the roadmap called out), then all decoding sequences
//! advance one token per step — so new requests join the batch *between*
//! steps without draining it ("continuous batching"). Chunks run through
//! `DecodeBackend::prefill` → `forward_batch_logits` as true `m_batch =
//! chunk_len` GEMMs (Psumbook build amortized), and non-final chunks pass
//! `want_logits = false` so the lm_head GEMM whose logits would be
//! discarded is skipped.
//!
//! Admission is gated twice: a bounded queue (reject) and, for
//! pool-backed backends, KV pages (`DecodeBackend::can_admit_prompt` —
//! the head request waits until its whole-lifetime page bound fits,
//! counted as a *deferral* in metrics, FIFO preserved). The prompt-aware
//! gate lets prefix-cache hits admit into a pool a cold prompt would
//! not fit: pinned shared pages are not allocated
//! (`DecodeBackend::reserve_with_prefix` starts prefill past the
//! matched positions), and a fully prefilled prompt publishes its full
//! pages back to the index (`DecodeBackend::publish_prefix`).
//!
//! **Preemption** (`KvConfig::preempt`): when the gate would defer a
//! candidate and a decoding slot of *strictly lower* priority exists,
//! the batcher swaps that victim out — spilling its KV to the host
//! arena (`PreemptMode::Spill`, with a recompute fallback when the
//! backend cannot spill or panics mid-spill) or dropping the KV and
//! queueing an exact replay stream (`PreemptMode::Recompute`). Victims
//! wait in a FIFO resume queue that outranks fresh admissions of the
//! same priority, so preempted work cannot starve; resumed replays
//! never re-sample (their tokens are already fixed), which keeps
//! preempted serving bit-exact with uncontended serving. Completion
//! reclaims the sequence's pages, unblocking the queue.
//! `coordinator::metrics` reports prefill/decode token counts,
//! preemption/resume counters and the pool occupancy snapshot per step.

use super::backend::{DecodeBackend, SlotStep};
use super::metrics::Metrics;
use super::request::{FinishReason, InFlight, Request, Response};
use crate::config::{PreemptMode, ServeConfig};
use crate::kvcache::SpillArena;
use crate::model::Sampler;
use crate::obs::trace::{self, SpanRecord};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

/// Span-schema name for a finish reason (`obs::trace` is stringly typed
/// so the trace schema stays decoupled from the enum).
fn finish_str(reason: FinishReason) -> &'static str {
    match reason {
        FinishReason::Length => trace::FINISH_LENGTH,
        FinishReason::Stop => trace::FINISH_STOP,
        FinishReason::Context => trace::FINISH_CONTEXT,
        FinishReason::Rejected => trace::FINISH_REJECTED,
    }
}

/// Slot state.
enum Slot {
    Free,
    Busy(InFlight),
}

/// The batcher owns the backend, the admission queue and the slot table.
pub struct Batcher {
    backend: Box<dyn DecodeBackend>,
    cfg: ServeConfig,
    slots: Vec<Slot>,
    queue: VecDeque<Request>,
    /// Preempted requests waiting to win a slot back, FIFO. Spill-mode
    /// entries have their KV in `spill_arena` (keyed by request id);
    /// recompute-mode entries carry their replay stream in
    /// `InFlight::replay`.
    resume_q: VecDeque<InFlight>,
    /// Host-memory KV of spilled (preempted) sequences.
    spill_arena: SpillArena,
    sampler: Sampler,
    pub metrics: Arc<Metrics>,
    finished: Vec<Response>,
    /// Rotating start slot for the prefill budget scan, so a tight budget
    /// round-robins across prefilling slots instead of starving the
    /// highest-numbered ones.
    prefill_rr: usize,
    /// Sampling seconds accumulated by `advance_after_logits` since the
    /// last drain — lets `step` subtract sampling out of the prefill and
    /// decode phases so `sched/*` attribution is exclusive.
    sample_s: f64,
}

impl Batcher {
    pub fn new(backend: Box<dyn DecodeBackend>, cfg: ServeConfig, metrics: Arc<Metrics>) -> Batcher {
        let n = backend.max_batch().min(cfg.max_batch.max(1));
        Batcher {
            backend,
            sampler: Sampler::new(cfg.temperature, 0x5EED),
            cfg,
            slots: (0..n).map(|_| Slot::Free).collect(),
            queue: VecDeque::new(),
            resume_q: VecDeque::new(),
            spill_arena: SpillArena::new(),
            metrics,
            finished: Vec::new(),
            prefill_rr: 0,
            sample_s: 0.0,
        }
    }

    /// Enqueue a request (admission control: bounded queue).
    pub fn submit(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.cfg.queue_capacity {
            self.metrics.on_reject();
            return false;
        }
        self.metrics.on_submit();
        self.queue.push_back(req);
        true
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s, Slot::Busy(_))).count()
    }

    pub fn is_idle(&self) -> bool {
        self.occupied() == 0 && self.queue.is_empty() && self.resume_q.is_empty()
    }

    /// A request's worst-case KV footprint in positions: the whole
    /// prompt plus its generation budget (backends clamp to the context
    /// window). Admission gates and reservations both use this bound, so
    /// an admitted sequence can never exhaust the pool mid-decode.
    fn lifetime_tokens(req: &Request) -> usize {
        req.prompt.len().saturating_add(req.max_new_tokens)
    }

    /// Move waiting requests into free slots (the router step). The
    /// resume queue goes first, FIFO — preempted work already won
    /// admission once, so fresh arrivals of the same priority must not
    /// starve it (only *strictly higher* priority may bypass a blocked
    /// resume head). Then the fresh queue, FIFO: the head request must
    /// fit the backend's KV pool ([`DecodeBackend::can_admit_prompt`]
    /// over its whole-lifetime footprint, discounting prefix-cache pins)
    /// or admission stops for this step — a deferral, counted in
    /// metrics. A candidate that does not fit may preempt a decoding
    /// slot of strictly lower priority ([`Batcher::preempt`]); with no
    /// victim, later completions reclaim pages and unblock it. A head
    /// request that could never fit even an *empty* pool is rejected
    /// with [`FinishReason::Rejected`] instead of deferring forever.
    fn admit(&mut self) {
        let mut deferred = false;
        'slots: for i in 0..self.slots.len() {
            if !matches!(self.slots[i], Slot::Free) {
                continue;
            }
            // Preempted work first (FIFO).
            let mut resume_blocked: Option<i32> = None;
            if let Some(f) = self.resume_q.pop_front() {
                match self.try_resume(i, f) {
                    Ok(()) => continue 'slots,
                    Err(f) => {
                        resume_blocked = Some(f.req.priority);
                        self.resume_q.push_front(f);
                    }
                }
            }
            // Drop queue heads that no amount of reclamation could ever
            // admit (footprint > whole pool) — deferring them would
            // livelock the queue behind an unsatisfiable request.
            while let Some(req) = self.queue.front() {
                if self.backend.can_ever_admit(Self::lifetime_tokens(req)) {
                    break;
                }
                let req = self.queue.pop_front().unwrap();
                let queue_wait_s = req.created.elapsed().as_secs_f64();
                self.metrics.on_infeasible(&SpanRecord {
                    id: req.id,
                    prompt_tokens: req.prompt.len(),
                    generated_tokens: 0,
                    finish: trace::FINISH_REJECTED,
                    queue_wait_s,
                    prefill_s: 0.0,
                    ttft_s: 0.0,
                    decode_s: 0.0,
                    latency_s: queue_wait_s,
                    tpot_s: 0.0,
                    prefill_chunks: 0,
                    preemptions: 0,
                    prefix_hit_tokens: 0,
                });
                self.finished.push(Response {
                    id: req.id,
                    tokens: Vec::new(),
                    finish: FinishReason::Rejected,
                    ttft_s: 0.0,
                    latency_s: 0.0,
                    tok_per_s: 0.0,
                });
            }
            let head_priority = match self.queue.front() {
                Some(req) => req.priority,
                None => {
                    if resume_blocked.is_some() {
                        deferred = true;
                    }
                    break;
                }
            };
            // A blocked resume head holds back fresh work at or below
            // its priority; strictly higher priority may bypass it.
            if let Some(rp) = resume_blocked {
                if head_priority <= rp {
                    deferred = true;
                    break;
                }
            }
            let req = self.queue.pop_front().unwrap();
            let need_tokens = Self::lifetime_tokens(&req);
            let fits = loop {
                if self.backend.can_admit_prompt(&req.prompt, need_tokens) {
                    break true;
                }
                if !self.preempt_lower_than(req.priority) {
                    break false;
                }
            };
            if !fits {
                self.queue.push_front(req);
                deferred = true;
                break;
            }
            self.backend.reset_slot(i);
            // Pin the prompt's cached prefix pages and pre-claim the
            // rest of the sequence's whole-lifetime pages, so the next
            // iteration's gate sees the reduced free count and decode
            // growth never races the free list. Prefill starts past the
            // matched positions.
            let matched = self.backend.reserve_with_prefix(i, &req.prompt, need_tokens);
            let mut f = InFlight::new(req);
            f.prefill_idx = matched;
            f.pos = matched;
            f.prefix_hit_tokens = matched;
            self.slots[i] = Slot::Busy(f);
        }
        if deferred {
            self.metrics.on_admit_defer();
        }
    }

    /// Try to put a preempted request back into `slot`. Spill-mode
    /// entries bulk-restore their saved KV; recompute-mode entries
    /// re-enter the admission path with their replay stream (and may hit
    /// the prefix cache for the prompt pages they published before
    /// preemption). Either path may itself preempt strictly
    /// lower-priority decoders. Returns the request on failure so the
    /// caller re-queues it.
    fn try_resume(&mut self, slot: usize, mut f: InFlight) -> Result<(), InFlight> {
        let need_tokens = Self::lifetime_tokens(&f.req);
        let pri = f.req.priority;
        self.backend.reset_slot(slot);
        if let Some(spill) = self.spill_arena.take(f.req.id) {
            loop {
                if self.backend.restore(slot, &spill, need_tokens) {
                    self.metrics.on_resume();
                    self.slots[slot] = Slot::Busy(f);
                    return Ok(());
                }
                if !self.preempt_lower_than(pri) {
                    break;
                }
            }
            self.spill_arena.insert(f.req.id, spill);
            Err(f)
        } else {
            loop {
                if self.backend.can_admit_prompt(f.feed(), need_tokens) {
                    break;
                }
                if !self.preempt_lower_than(pri) {
                    return Err(f);
                }
            }
            let matched = self.backend.reserve_with_prefix(slot, f.feed(), need_tokens);
            f.prefill_idx = matched;
            f.pos = matched;
            f.prefix_hit_tokens += matched;
            self.metrics.on_resume();
            self.slots[slot] = Slot::Busy(f);
            Ok(())
        }
    }

    /// Preempt one decoding slot of *strictly* lower priority than
    /// `pri`, if any (lowest priority first; ties broken toward the
    /// longest sequence — the most pages reclaimed). Returns whether a
    /// victim was swapped out (its pages are then back in the pool).
    fn preempt_lower_than(&mut self, pri: i32) -> bool {
        if self.cfg.kv.preempt == PreemptMode::Off {
            return false;
        }
        match self.find_victim(pri) {
            Some(j) => {
                self.preempt(j);
                true
            }
            None => false,
        }
    }

    /// The preemption victim for a candidate of priority `pri`: a
    /// decoding (never prefilling) slot with strictly lower priority,
    /// preferring the lowest priority and, among equals, the longest
    /// sequence.
    fn find_victim(&self, pri: i32) -> Option<usize> {
        let mut best: Option<(i32, usize, usize)> = None;
        for (j, s) in self.slots.iter().enumerate() {
            if let Slot::Busy(f) = s {
                if f.is_prefilling() || f.req.priority >= pri {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((bp, bpos, _)) => {
                        f.req.priority < bp || (f.req.priority == bp && f.pos > bpos)
                    }
                };
                if better {
                    best = Some((f.req.priority, f.pos, j));
                }
            }
        }
        best.map(|(_, _, j)| j)
    }

    /// Swap the decoding sequence in `victim` out of its slot. Spill
    /// mode copies its KV to the host arena (falling back to recompute
    /// when the backend cannot spill, or panics mid-spill — the pages
    /// are still held then, so `reset_slot` reclaims them); recompute
    /// mode drops the KV and queues an exact replay stream: the prompt
    /// plus every sampled token except the last, which becomes the next
    /// decode input once the replay has been prefilled. Either way the
    /// victim's pages are back in the pool when this returns.
    fn preempt(&mut self, victim: usize) {
        let Slot::Busy(mut f) = std::mem::replace(&mut self.slots[victim], Slot::Free) else {
            unreachable!("preempt targets busy slots")
        };
        f.preemptions += 1;
        let mut spilled = false;
        if self.cfg.kv.preempt == PreemptMode::Spill {
            if let Ok(Some(s)) = catch_unwind(AssertUnwindSafe(|| self.backend.spill(victim))) {
                self.spill_arena.insert(f.req.id, s);
                spilled = true;
            }
        }
        if !spilled {
            self.backend.reset_slot(victim);
            let g = f.generated.len();
            debug_assert!(g > 0, "victims are decoding, so they sampled at least one token");
            let mut replay = f.req.prompt.clone();
            replay.extend_from_slice(&f.generated[..g.saturating_sub(1)]);
            f.replay = Some(replay);
            f.prefill_idx = 0;
            f.pos = 0;
        }
        self.metrics.on_preempt(spilled);
        self.resume_q.push_back(f);
    }

    /// Run one engine step: batched prefill across prefilling slots under
    /// the shared `prefill_budget` token cap (decode stall per step is
    /// bounded by the budget, not by the number of prefilling slots),
    /// then one decode token for every decoding slot. Returns the number
    /// of slots advanced (0 ⇒ idle).
    pub fn step(&mut self) -> usize {
        let ta = Instant::now();
        self.admit();
        let admit_s = ta.elapsed().as_secs_f64();
        let max_seq = self.backend.max_seq();
        let t0 = Instant::now();
        let mut advanced = 0usize;
        let mut prefill_tokens = 0usize;
        let n = self.slots.len();
        let mut just_prefilled = vec![false; n];

        // Phase 1: batched prefill under the shared per-step token
        // budget, scanned round-robin from a rotating start slot. A
        // partially prefilled slot (or one skipped when the budget ran
        // out) simply resumes on a later step; the final position's
        // logits seed the first sampled token.
        let mut budget = self.cfg.prefill_budget.max(1);
        let start = if n > 0 { self.prefill_rr % n } else { 0 };
        for off in 0..n {
            if budget == 0 {
                break;
            }
            let i = (start + off) % n;
            let (feed, pos, finishes_feed, want_logits) = match &self.slots[i] {
                Slot::Busy(f) if f.is_prefilling() => {
                    // The feed is the prompt, or the replay stream while
                    // resuming a recompute-mode preemption.
                    let remaining = &f.feed()[f.prefill_idx..];
                    // Clamp to the context window (an over-long prompt
                    // finishes with `FinishReason::Context` below) and to
                    // what's left of the shared step budget.
                    let room = max_seq.saturating_sub(f.pos).min(budget);
                    if room == 0 {
                        continue;
                    }
                    let take = remaining.len().min(room);
                    let fin = take == remaining.len();
                    // Logits are only needed when this chunk completes a
                    // *prompt* (they seed the first sampled token). A
                    // replay's final chunk never samples — its next token
                    // is already fixed — so the lm_head GEMM is skipped
                    // for every replay chunk too.
                    (remaining[..take].to_vec(), f.pos, fin, fin && f.generated.is_empty())
                }
                _ => continue,
            };
            let logits = self
                .backend
                .prefill(i, &feed, pos, want_logits)
                .expect("backend prefill failed");
            budget -= feed.len();
            prefill_tokens += feed.len();
            advanced += 1;
            just_prefilled[i] = true;
            let Slot::Busy(f) = &mut self.slots[i] else { unreachable!() };
            f.prefill_idx += feed.len();
            f.pos += feed.len();
            f.prefill_chunks += 1;
            let publish = if finishes_feed {
                f.prefill_done = Some(Instant::now());
                // The prompt's pages are complete (a replay stream
                // starts with the prompt, so this holds on resume too)
                // and immutable from here on: publish the full ones for
                // other admissions to pin.
                Some(f.req.prompt.clone())
            } else {
                None
            };
            if let Some(prompt) = publish {
                self.backend.publish_prefix(i, &prompt);
            }
            self.advance_after_logits(i, logits.as_deref().unwrap_or(&[]), max_seq, false);
        }
        if n > 0 {
            self.prefill_rr = (self.prefill_rr + 1) % n;
        }
        // Sampling time inside phase 1 (final-chunk logits seed the first
        // token) — drained so the sched/* phases stay exclusive.
        let sample_p1 = std::mem::take(&mut self.sample_s);
        let prefill_s = t0.elapsed().as_secs_f64() - sample_p1;
        let t1 = Instant::now();

        // Phase 2: one decode token for every slot already decoding.
        let mut steps: Vec<SlotStep> = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            if let Slot::Busy(f) = s {
                if !f.is_prefilling() && !just_prefilled[i] {
                    steps.push(SlotStep { slot: i, token: f.next_input(), pos: f.pos });
                }
            }
        }
        let decode_n = steps.len();
        if decode_n > 0 {
            let logits = self.backend.step(&steps).expect("backend step failed");
            advanced += decode_n;
            for (ss, lg) in steps.iter().zip(&logits) {
                let Slot::Busy(f) = &mut self.slots[ss.slot] else { unreachable!() };
                f.pos += 1;
                self.advance_after_logits(ss.slot, lg, max_seq, true);
            }
        }
        let sample_p2 = std::mem::take(&mut self.sample_s);
        let decode_s = t1.elapsed().as_secs_f64() - sample_p2;
        if advanced > 0 {
            self.metrics.on_step(advanced, prefill_tokens, decode_n, t0.elapsed().as_secs_f64());
            // Scheduler phase attribution: prefill and decode wall time
            // with sampling carved out into its own phase.
            self.metrics.on_step_phases(&[
                ("sched/admit", admit_s),
                ("sched/prefill", prefill_s.max(0.0)),
                ("sched/decode", decode_s.max(0.0)),
                ("sched/sample", sample_p1 + sample_p2),
            ]);
            // Pool occupancy gauge (post-step, so reclamation shows up).
            if let Some(kv) = self.backend.kv_stats() {
                self.metrics.on_kv(kv);
            }
            // Engine work gauge (cumulative counters: latest wins).
            if let Some(eng) = self.backend.engine_counters() {
                self.metrics.on_engine(eng);
            }
            // Kernel dispatch gauge (fixed at backend construction, so
            // re-recording the same value each step is idempotent).
            if let Some(sel) = self.backend.kernel_sel() {
                self.metrics.on_kernel(sel);
            }
            // Model forward phase gauge (cumulative timer: latest wins).
            if let Some(p) = self.backend.phases() {
                self.metrics.on_model_phases(p);
            }
            // Scratch working-set gauge (high-water capacities: latest
            // snapshot is the serving high-water mark).
            if let Some(parts) = self.backend.scratch_parts() {
                self.metrics.on_footprint(parts);
            }
        }
        advanced
    }

    /// Shared post-GEMM bookkeeping for a slot whose position just
    /// advanced past `logits`' token: sample when decoding, then retire
    /// the sequence if any finish condition hit. `decode_phase` is false
    /// for prefill-chunk calls — there, sampling happens only off a
    /// *prompt's* final logits (`generated` still empty); a finished
    /// recompute replay must not re-sample the token it already holds.
    fn advance_after_logits(&mut self, slot_idx: usize, logits: &[f32], max_seq: usize, decode_phase: bool) {
        let slot = &mut self.slots[slot_idx];
        let Slot::Busy(f) = slot else { unreachable!() };
        let mut finish: Option<FinishReason> = None;
        if !f.is_prefilling() && (decode_phase || f.generated.is_empty()) {
            // Sample the next token (valid both for the final prefill
            // position's logits and for decode steps).
            let ts = Instant::now();
            let tok = self.sampler.sample(logits);
            self.sample_s += ts.elapsed().as_secs_f64();
            if f.first_token.is_none() {
                f.first_token = Some(Instant::now());
            }
            f.generated.push(tok);
            if f.req.stop_token == Some(tok) {
                finish = Some(FinishReason::Stop);
            } else if f.generated.len() >= f.req.max_new_tokens {
                finish = Some(FinishReason::Length);
            }
        }
        if finish.is_none() && f.pos >= max_seq {
            finish = Some(FinishReason::Context);
        }
        if let Some(reason) = finish {
            // Lifecycle attribution, all anchored at submit time
            // (`req.created`) so TTFT/latency are client-visible:
            // queue wait → prefill → first token → decode → finish.
            let now = Instant::now();
            let created = f.req.created;
            let ttft = f.first_token.map(|t| (t - created).as_secs_f64()).unwrap_or_default();
            let latency = (now - created).as_secs_f64();
            let decode_time = f.first_token.map(|t| (now - t).as_secs_f64()).unwrap_or(0.0);
            let n_gen = f.generated.len();
            let span = SpanRecord {
                id: f.req.id,
                prompt_tokens: f.req.prompt.len(),
                generated_tokens: n_gen,
                finish: finish_str(reason),
                queue_wait_s: (f.admitted - created).as_secs_f64(),
                prefill_s: f.prefill_done.map(|t| (t - f.admitted).as_secs_f64()).unwrap_or(0.0),
                ttft_s: ttft,
                decode_s: decode_time,
                latency_s: latency,
                tpot_s: if n_gen > 1 { decode_time / (n_gen - 1) as f64 } else { 0.0 },
                prefill_chunks: f.prefill_chunks,
                preemptions: f.preemptions,
                prefix_hit_tokens: f.prefix_hit_tokens,
            };
            let resp = Response {
                id: f.req.id,
                tokens: std::mem::take(&mut f.generated),
                finish: reason,
                ttft_s: ttft,
                latency_s: latency,
                tok_per_s: if n_gen > 1 {
                    (n_gen - 1) as f64 / decode_time.max(1e-9)
                } else {
                    0.0
                },
            };
            self.metrics.on_complete(&span);
            self.finished.push(resp);
            *slot = Slot::Free;
            // Reclaim the sequence's KV pages immediately (not at the
            // slot's next assignment) so deferred requests can admit as
            // soon as capacity exists.
            self.backend.reset_slot(slot_idx);
        }
    }

    /// Drain finished responses.
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// Run until every queued/in-flight request completes; returns all
    /// responses. (The offline/batch entrypoint; the server wraps `step`
    /// for online serving.)
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        while !self.is_idle() {
            self.step();
            out.extend(self.take_finished());
        }
        out
    }

    pub fn backend_label(&self) -> String {
        self.backend.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::coordinator::backend::NativeBackend;
    use crate::model::{EngineKind, ModelWeights};

    fn mk_batcher(max_batch: usize, queue_cap: usize) -> Batcher {
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let backend = Box::new(NativeBackend::new(&w, EngineKind::Dense, max_batch));
        let cfg = ServeConfig {
            max_batch,
            queue_capacity: queue_cap,
            max_new_tokens: 4,
            temperature: 0.0,
            ..Default::default()
        };
        Batcher::new(backend, cfg, Arc::new(Metrics::new()))
    }

    #[test]
    fn single_request_completes_with_exact_token_budget() {
        let mut b = mk_batcher(2, 8);
        b.submit(Request::new(7, vec![1, 2, 3], 4));
        let out = b.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 7);
        assert_eq!(out[0].tokens.len(), 4);
        assert_eq!(out[0].finish, FinishReason::Length);
    }

    #[test]
    fn batched_equals_sequential_greedy() {
        // Continuous batching must not change greedy outputs.
        let prompts: Vec<Vec<usize>> = vec![vec![5, 6], vec![100, 101, 102], vec![9]];
        let mut seq_out = Vec::new();
        for p in &prompts {
            let mut b = mk_batcher(1, 8);
            b.submit(Request::new(0, p.clone(), 4));
            seq_out.push(b.run_to_completion().remove(0).tokens);
        }
        let mut b = mk_batcher(3, 8);
        for (i, p) in prompts.iter().enumerate() {
            b.submit(Request::new(i as u64, p.clone(), 4));
        }
        let mut batched = b.run_to_completion();
        batched.sort_by_key(|r| r.id);
        for (i, r) in batched.iter().enumerate() {
            assert_eq!(r.tokens, seq_out[i], "request {i} diverged under batching");
        }
    }

    #[test]
    fn queue_overflow_rejects() {
        let mut b = mk_batcher(1, 2);
        assert!(b.submit(Request::new(1, vec![1], 2)));
        assert!(b.submit(Request::new(2, vec![1], 2)));
        assert!(!b.submit(Request::new(3, vec![1], 2)));
        assert_eq!(b.metrics.report().rejected, 1);
    }

    #[test]
    fn more_requests_than_slots_all_complete() {
        let mut b = mk_batcher(2, 16);
        for i in 0..6 {
            b.submit(Request::new(i, vec![(i as usize) % 200 + 1, 2], 3));
        }
        let out = b.run_to_completion();
        assert_eq!(out.len(), 6);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        // Slots were actually shared.
        assert!(b.metrics.report().mean_batch > 1.0);
    }

    #[test]
    fn stop_token_halts_generation() {
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let backend = Box::new(NativeBackend::new(&w, EngineKind::Dense, 1));
        let cfg = ServeConfig { max_batch: 1, max_new_tokens: 64, temperature: 0.0, ..Default::default() };
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        // Find what greedy generates first, then use it as the stop token.
        let mut probe = mk_batcher(1, 4);
        probe.submit(Request::new(0, vec![1, 2], 1));
        let first = probe.run_to_completion()[0].tokens[0];
        let mut req = Request::new(1, vec![1, 2], 64);
        req.stop_token = Some(first);
        b.submit(req);
        let out = b.run_to_completion();
        assert_eq!(out[0].finish, FinishReason::Stop);
        assert_eq!(out[0].tokens.len(), 1);
    }

    #[test]
    fn context_limit_terminates() {
        let mut b = mk_batcher(1, 4);
        let long_prompt: Vec<usize> = (0..120).map(|i| (i % 250) + 1).collect();
        b.submit(Request::new(1, long_prompt, 1000));
        let out = b.run_to_completion();
        assert_eq!(out[0].finish, FinishReason::Context);
        // Positions 0..119 hold the prompt; forwards at 119..=127 each
        // produce one sampled token ⇒ 9 generated, all 128 positions used.
        assert_eq!(out[0].tokens.len(), 9);
    }

    #[test]
    fn shared_prefill_budget_bounds_tokens_per_step() {
        // Two slots, both prefilling 40-token prompts, budget 16: each
        // step consumes at most 16 prompt tokens *total* (not per slot),
        // and the round-robin start lets both slots make progress.
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let backend = Box::new(NativeBackend::new(&w, EngineKind::Dense, 2));
        let cfg = ServeConfig {
            max_batch: 2,
            max_new_tokens: 2,
            temperature: 0.0,
            prefill_budget: 16,
            ..Default::default()
        };
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        let prompt: Vec<usize> = (0..40).map(|i| (i % 200) + 1).collect();
        b.submit(Request::new(0, prompt.clone(), 2));
        b.submit(Request::new(1, prompt.clone(), 2));
        let mut before = 0u64;
        while !b.is_idle() {
            b.step();
            let after = b.metrics.report().prefill_tokens;
            assert!(after - before <= 16, "step consumed {} prefill tokens", after - before);
            before = after;
        }
        let out = b.take_finished();
        assert_eq!(out.len(), 2);
        assert_eq!(b.metrics.report().prefill_tokens, 80);
    }

    #[test]
    fn budget_constrained_batched_equals_sequential_greedy() {
        // A tight shared budget changes scheduling, never outputs.
        let prompts: Vec<Vec<usize>> = vec![
            (0..20).map(|i| (i * 3) % 200 + 1).collect(),
            (0..11).map(|i| (i * 7) % 200 + 1).collect(),
            vec![9, 10, 11],
        ];
        let mk = |batch: usize| {
            let w = ModelWeights::random(ModelConfig::tiny(), 3);
            let backend = Box::new(NativeBackend::new(&w, EngineKind::Dense, batch));
            let cfg = ServeConfig {
                max_batch: batch,
                max_new_tokens: 4,
                temperature: 0.0,
                prefill_budget: 8,
                ..Default::default()
            };
            Batcher::new(backend, cfg, Arc::new(Metrics::new()))
        };
        let mut seq_out = Vec::new();
        for p in &prompts {
            let mut b = mk(1);
            b.submit(Request::new(0, p.clone(), 4));
            seq_out.push(b.run_to_completion().remove(0).tokens);
        }
        let mut b = mk(3);
        for (i, p) in prompts.iter().enumerate() {
            b.submit(Request::new(i as u64, p.clone(), 4));
        }
        let mut batched = b.run_to_completion();
        batched.sort_by_key(|r| r.id);
        for (i, r) in batched.iter().enumerate() {
            assert_eq!(r.tokens, seq_out[i], "request {i} diverged under a tight budget");
        }
    }

    #[test]
    fn pool_exhaustion_defers_admission_then_reclaims() {
        use crate::config::KvConfig;
        // Pool of 2 pages × 4 tokens: one request's lifetime footprint
        // (3 prompt + 3 generated → 2 pages) takes the whole pool, so a
        // second request must wait for the first to finish and release
        // its pages — admission is gated by pool pages, not by the 4
        // free slots.
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let kv = KvConfig { page_size: 4, pool_pages: 2, ..KvConfig::default() };
        let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 4, &kv));
        let cfg = ServeConfig {
            max_batch: 4,
            max_new_tokens: 3,
            temperature: 0.0,
            queue_capacity: 8,
            ..Default::default()
        };
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        for i in 0..3 {
            b.submit(Request::new(i, vec![1, 2, 3], 3));
        }
        // First step: only one request fits the pool; the rest defer.
        b.step();
        assert_eq!(b.occupied(), 1, "pool must gate admission below slot count");
        assert!(b.queue_depth() >= 1);
        let out = b.run_to_completion();
        assert_eq!(out.len(), 3, "deferred requests complete after reclamation");
        assert!(out.iter().all(|r| r.tokens.len() == 3), "deferral must not truncate");
        let report = b.metrics.report();
        assert!(report.deferred > 0, "deferrals must be observable");
        // Full reclamation: every page is back on the free list.
        let kv_stats = report.kv.expect("pool-backed backend reports kv stats");
        assert_eq!(kv_stats.pool.free_pages, kv_stats.pool.total_pages);
        assert!(kv_stats.pool.freed >= 3, "each completed request frees its pages");
    }

    #[test]
    fn impossible_request_rejected_not_livelocked() {
        use crate::config::KvConfig;
        // Pool capacity is 2 pages × 16 tokens = 32 positions; a request
        // whose lifetime footprint (10 prompt + 30 generated = 40) can
        // never fit must be rejected — deferring it would head-of-line
        // block the queue forever. A feasible request behind it must
        // still be served.
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let kv = KvConfig { page_size: 16, pool_pages: 2, ..KvConfig::default() };
        let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
        let cfg = ServeConfig {
            max_batch: 2,
            max_new_tokens: 30,
            temperature: 0.0,
            queue_capacity: 8,
            ..Default::default()
        };
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        b.submit(Request::new(1, (1..=10).collect(), 30));
        b.submit(Request::new(2, vec![1, 2, 3], 4));
        let mut out = b.run_to_completion();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].finish, FinishReason::Rejected);
        assert!(out[0].tokens.is_empty());
        assert_eq!(out[1].finish, FinishReason::Length);
        assert_eq!(out[1].tokens.len(), 4);
        let report = b.metrics.report();
        assert_eq!(report.infeasible, 1);
        assert_eq!(report.rejected, 0, "queue-full rejects are a separate counter");
    }

    /// Contended serving (a high-priority arrival preempts a decoding
    /// low-priority slot) must produce bitwise the tokens of uncontended
    /// serving, in both preemption modes.
    fn preemption_is_bit_exact(mode: crate::config::PreemptMode) {
        use crate::config::{KvConfig, PreemptMode};
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        // 4 pages × 4 tokens: each request's lifetime (3 prompt + 6
        // generated → 3 pages) leaves too little for a second, so the
        // high-priority arrival must preempt.
        let kv = KvConfig { page_size: 4, pool_pages: 4, preempt: mode, ..KvConfig::default() };
        let cfg = ServeConfig {
            max_batch: 2,
            max_new_tokens: 6,
            temperature: 0.0,
            queue_capacity: 8,
            kv: kv.clone(),
            ..Default::default()
        };
        // Uncontended references: each request alone in a fresh batcher.
        let reference = |prompt: Vec<usize>| {
            let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
            let mut b = Batcher::new(backend, cfg.clone(), Arc::new(Metrics::new()));
            b.submit(Request::new(0, prompt, 6));
            b.run_to_completion().remove(0).tokens
        };
        let want_low = reference(vec![1, 2, 3]);
        let want_high = reference(vec![4, 5, 6]);

        let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        b.submit(Request::new(1, vec![1, 2, 3], 6)); // priority 0
        b.step(); // prefill low
        b.step(); // low decodes — a valid preemption victim now
        b.submit(Request::new(2, vec![4, 5, 6], 6).with_priority(1));
        let mut out = b.run_to_completion();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].tokens, want_low, "preempted request diverged");
        assert_eq!(out[1].tokens, want_high, "preempting request diverged");
        assert!(out.iter().all(|r| r.finish == FinishReason::Length));
        let report = b.metrics.report();
        assert!(report.preemptions >= 1, "the high-priority arrival must preempt");
        assert_eq!(report.resumes as usize, report.preemptions as usize, "every victim resumes");
        match mode {
            PreemptMode::Spill => assert_eq!(report.preempt_spills, report.preemptions),
            PreemptMode::Recompute => assert_eq!(report.preempt_recomputes, report.preemptions),
            PreemptMode::Off => unreachable!(),
        }
        // Victim spans carry their preemption count.
        assert!(report.spans.iter().any(|s| s.id == 1 && s.preemptions >= 1));
        // Full reclamation at drain.
        let kv_stats = report.kv.expect("pool-backed backend reports kv stats");
        assert_eq!(kv_stats.pool.used_pages, 0);
        assert_eq!(kv_stats.pool.live_refs, 0);
        assert_eq!(kv_stats.pool.free_pages, kv_stats.pool.total_pages);
    }

    #[test]
    fn spill_preemption_bit_exact_and_fully_reclaimed() {
        preemption_is_bit_exact(crate::config::PreemptMode::Spill);
    }

    #[test]
    fn recompute_preemption_bit_exact_and_fully_reclaimed() {
        preemption_is_bit_exact(crate::config::PreemptMode::Recompute);
    }

    #[test]
    fn preempt_off_never_preempts_even_across_priorities() {
        use crate::config::{KvConfig, PreemptMode};
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let kv =
            KvConfig { page_size: 4, pool_pages: 4, preempt: PreemptMode::Off, ..KvConfig::default() };
        let cfg = ServeConfig {
            max_batch: 2,
            max_new_tokens: 6,
            temperature: 0.0,
            queue_capacity: 8,
            kv: kv.clone(),
            ..Default::default()
        };
        let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        b.submit(Request::new(1, vec![1, 2, 3], 6));
        b.step();
        b.step();
        b.submit(Request::new(2, vec![4, 5, 6], 6).with_priority(1));
        let out = b.run_to_completion();
        assert_eq!(out.len(), 2, "the high-priority request waits for reclamation instead");
        let report = b.metrics.report();
        assert_eq!(report.preemptions, 0);
        assert!(report.deferred > 0, "it defers while the low-priority slot drains");
    }

    #[test]
    fn shared_prompt_second_admission_hits_prefix_cache() {
        use crate::config::KvConfig;
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let kv = KvConfig { page_size: 4, pool_pages: 16, ..KvConfig::default() };
        let cfg = ServeConfig {
            max_batch: 2,
            max_new_tokens: 2,
            temperature: 0.0,
            queue_capacity: 8,
            kv: kv.clone(),
            ..Default::default()
        };
        let prompt: Vec<usize> = (1..=9).collect(); // 2 full pages + 1
        // Sequential reference for the same prompt.
        let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
        let mut solo = Batcher::new(backend, cfg.clone(), Arc::new(Metrics::new()));
        solo.submit(Request::new(0, prompt.clone(), 2));
        let want = solo.run_to_completion().remove(0).tokens;

        let backend = Box::new(NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv));
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        b.submit(Request::new(1, prompt.clone(), 2));
        let first = loop {
            b.step();
            let done = b.take_finished();
            if !done.is_empty() {
                break done;
            }
        };
        assert_eq!(first[0].tokens, want);
        // Second request with the same prompt: its first 2 pages (8
        // tokens) come from the cache.
        b.submit(Request::new(2, prompt.clone(), 2));
        let out = b.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens, want, "cache hit must not change outputs");
        let report = b.metrics.report();
        let kv_stats = report.kv.expect("kv stats");
        assert_eq!(kv_stats.pool.prefix_hits, 1, "second admission hits");
        assert_eq!(kv_stats.pool.prefix_hit_tokens, 8);
        assert!(report.spans.iter().any(|s| s.id == 2 && s.prefix_hit_tokens == 8));
        assert!((report.prefix_hit_rate() - 0.5).abs() < 1e-12, "1 hit / 2 probes");
        assert_eq!(kv_stats.pool.used_pages, 0, "drained");
        assert_eq!(kv_stats.pool.free_pages, kv_stats.pool.total_pages);
    }
}
