//! Continuous (iteration-level) dynamic batcher.
//!
//! Orca/vLLM-style scheduling over a two-phase step: prefilling
//! sequences consume their prompt in **batched chunks of up to
//! `MAX_PREFILL_CHUNK` tokens per step** (`DecodeBackend::prefill` →
//! `forward_batch`, true `m_batch = chunk_len` GEMMs, where the Psumbook
//! build amortizes — while the chunk cap bounds how long a long prompt
//! can stall decoding slots), then all decoding sequences advance one
//! token per step — so new requests join the batch *between* steps
//! without draining it ("continuous batching"). `coordinator::metrics`
//! reports prefill and decode **token** counts separately, making the
//! prefill/decode split of a serving window directly observable.

use super::backend::{DecodeBackend, SlotStep};
use super::metrics::Metrics;
use super::request::{FinishReason, InFlight, Request, Response};
use crate::config::ServeConfig;
use crate::model::{Sampler, MAX_PREFILL_CHUNK};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// Slot state.
enum Slot {
    Free,
    Busy(InFlight),
}

/// The batcher owns the backend, the admission queue and the slot table.
pub struct Batcher {
    backend: Box<dyn DecodeBackend>,
    cfg: ServeConfig,
    slots: Vec<Slot>,
    queue: VecDeque<Request>,
    sampler: Sampler,
    pub metrics: Arc<Metrics>,
    finished: Vec<Response>,
}

impl Batcher {
    pub fn new(backend: Box<dyn DecodeBackend>, cfg: ServeConfig, metrics: Arc<Metrics>) -> Batcher {
        let n = backend.max_batch().min(cfg.max_batch.max(1));
        Batcher {
            backend,
            sampler: Sampler::new(cfg.temperature, 0x5EED),
            cfg,
            slots: (0..n).map(|_| Slot::Free).collect(),
            queue: VecDeque::new(),
            metrics,
            finished: Vec::new(),
        }
    }

    /// Enqueue a request (admission control: bounded queue).
    pub fn submit(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.cfg.queue_capacity {
            self.metrics.on_reject();
            return false;
        }
        self.metrics.on_submit();
        self.queue.push_back(req);
        true
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s, Slot::Busy(_))).count()
    }

    pub fn is_idle(&self) -> bool {
        self.occupied() == 0 && self.queue.is_empty()
    }

    /// Move queued requests into free slots (the router step).
    fn admit(&mut self) {
        for i in 0..self.slots.len() {
            if self.queue.is_empty() {
                break;
            }
            if matches!(self.slots[i], Slot::Free) {
                let req = self.queue.pop_front().unwrap();
                self.backend.reset_slot(i);
                self.slots[i] = Slot::Busy(InFlight::new(req));
            }
        }
    }

    /// Run one engine step: batched prefill for every prefilling slot
    /// (up to one `MAX_PREFILL_CHUNK`-token chunk per slot per step, so a
    /// long prompt cannot stall decoding slots for more than one chunk —
    /// bounded head-of-line blocking), then one decode token for every
    /// decoding slot. Returns the number of slots advanced (0 ⇒ idle).
    pub fn step(&mut self) -> usize {
        self.admit();
        let max_seq = self.backend.max_seq();
        let t0 = Instant::now();
        let mut advanced = 0usize;
        let mut prefill_tokens = 0usize;
        let mut just_prefilled = vec![false; self.slots.len()];

        // Phase 1: batched prefill. Each prefilling slot consumes up to
        // one engine-batch-sized prompt chunk per step (a partially
        // prefilled slot simply resumes next step); the final position's
        // logits seed the first sampled token.
        for i in 0..self.slots.len() {
            let (feed, pos) = match &self.slots[i] {
                Slot::Busy(f) if f.is_prefilling() => {
                    let remaining = &f.req.prompt[f.prefill_idx..];
                    // Clamp to the context window (an over-long prompt
                    // finishes with `FinishReason::Context` below) and to
                    // the per-step chunk budget.
                    let room = max_seq.saturating_sub(f.pos).min(MAX_PREFILL_CHUNK);
                    (remaining[..remaining.len().min(room)].to_vec(), f.pos)
                }
                _ => continue,
            };
            let logits = self.backend.prefill(i, &feed, pos).expect("backend prefill failed");
            prefill_tokens += feed.len();
            advanced += 1;
            just_prefilled[i] = true;
            let Slot::Busy(f) = &mut self.slots[i] else { unreachable!() };
            f.prefill_idx += feed.len();
            f.pos += feed.len();
            self.advance_after_logits(i, &logits, max_seq);
        }

        // Phase 2: one decode token for every slot already decoding.
        let mut steps: Vec<SlotStep> = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            if let Slot::Busy(f) = s {
                if !f.is_prefilling() && !just_prefilled[i] {
                    steps.push(SlotStep { slot: i, token: f.next_input(), pos: f.pos });
                }
            }
        }
        let decode_n = steps.len();
        if decode_n > 0 {
            let logits = self.backend.step(&steps).expect("backend step failed");
            advanced += decode_n;
            for (ss, lg) in steps.iter().zip(&logits) {
                let Slot::Busy(f) = &mut self.slots[ss.slot] else { unreachable!() };
                f.pos += 1;
                self.advance_after_logits(ss.slot, lg, max_seq);
            }
        }
        if advanced > 0 {
            self.metrics.on_step(advanced, prefill_tokens, decode_n, t0.elapsed().as_secs_f64());
        }
        advanced
    }

    /// Shared post-GEMM bookkeeping for a slot whose position just
    /// advanced past `logits`' token: sample when decoding, then retire
    /// the sequence if any finish condition hit.
    fn advance_after_logits(&mut self, slot_idx: usize, logits: &[f32], max_seq: usize) {
        let slot = &mut self.slots[slot_idx];
        let Slot::Busy(f) = slot else { unreachable!() };
        let mut finish: Option<FinishReason> = None;
        if !f.is_prefilling() {
            // Sample the next token (valid both for the final prefill
            // position's logits and for decode steps).
            let tok = self.sampler.sample(logits);
            if f.first_token.is_none() {
                f.first_token = Some(Instant::now());
            }
            f.generated.push(tok);
            if f.req.stop_token == Some(tok) {
                finish = Some(FinishReason::Stop);
            } else if f.generated.len() >= f.req.max_new_tokens {
                finish = Some(FinishReason::Length);
            }
        }
        if finish.is_none() && f.pos >= max_seq {
            finish = Some(FinishReason::Context);
        }
        if let Some(reason) = finish {
            let ttft = f
                .first_token
                .map(|t| (t - f.submitted).as_secs_f64())
                .unwrap_or_default();
            let latency = f.submitted.elapsed().as_secs_f64();
            let decode_time = (latency - ttft).max(1e-9);
            let n_gen = f.generated.len();
            let resp = Response {
                id: f.req.id,
                tokens: std::mem::take(&mut f.generated),
                finish: reason,
                ttft_s: ttft,
                latency_s: latency,
                tok_per_s: if n_gen > 1 { (n_gen - 1) as f64 / decode_time } else { 0.0 },
            };
            self.metrics.on_complete(ttft, latency);
            self.finished.push(resp);
            *slot = Slot::Free;
        }
    }

    /// Drain finished responses.
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// Run until every queued/in-flight request completes; returns all
    /// responses. (The offline/batch entrypoint; the server wraps `step`
    /// for online serving.)
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        while !self.is_idle() {
            self.step();
            out.extend(self.take_finished());
        }
        out
    }

    pub fn backend_label(&self) -> String {
        self.backend.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::coordinator::backend::NativeBackend;
    use crate::model::{EngineKind, ModelWeights};

    fn mk_batcher(max_batch: usize, queue_cap: usize) -> Batcher {
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let backend = Box::new(NativeBackend::new(&w, EngineKind::Dense, max_batch));
        let cfg = ServeConfig {
            max_batch,
            queue_capacity: queue_cap,
            max_new_tokens: 4,
            temperature: 0.0,
            ..Default::default()
        };
        Batcher::new(backend, cfg, Arc::new(Metrics::new()))
    }

    #[test]
    fn single_request_completes_with_exact_token_budget() {
        let mut b = mk_batcher(2, 8);
        b.submit(Request::new(7, vec![1, 2, 3], 4));
        let out = b.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 7);
        assert_eq!(out[0].tokens.len(), 4);
        assert_eq!(out[0].finish, FinishReason::Length);
    }

    #[test]
    fn batched_equals_sequential_greedy() {
        // Continuous batching must not change greedy outputs.
        let prompts: Vec<Vec<usize>> = vec![vec![5, 6], vec![100, 101, 102], vec![9]];
        let mut seq_out = Vec::new();
        for p in &prompts {
            let mut b = mk_batcher(1, 8);
            b.submit(Request::new(0, p.clone(), 4));
            seq_out.push(b.run_to_completion().remove(0).tokens);
        }
        let mut b = mk_batcher(3, 8);
        for (i, p) in prompts.iter().enumerate() {
            b.submit(Request::new(i as u64, p.clone(), 4));
        }
        let mut batched = b.run_to_completion();
        batched.sort_by_key(|r| r.id);
        for (i, r) in batched.iter().enumerate() {
            assert_eq!(r.tokens, seq_out[i], "request {i} diverged under batching");
        }
    }

    #[test]
    fn queue_overflow_rejects() {
        let mut b = mk_batcher(1, 2);
        assert!(b.submit(Request::new(1, vec![1], 2)));
        assert!(b.submit(Request::new(2, vec![1], 2)));
        assert!(!b.submit(Request::new(3, vec![1], 2)));
        assert_eq!(b.metrics.report().rejected, 1);
    }

    #[test]
    fn more_requests_than_slots_all_complete() {
        let mut b = mk_batcher(2, 16);
        for i in 0..6 {
            b.submit(Request::new(i, vec![(i as usize) % 200 + 1, 2], 3));
        }
        let out = b.run_to_completion();
        assert_eq!(out.len(), 6);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        // Slots were actually shared.
        assert!(b.metrics.report().mean_batch > 1.0);
    }

    #[test]
    fn stop_token_halts_generation() {
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let backend = Box::new(NativeBackend::new(&w, EngineKind::Dense, 1));
        let cfg = ServeConfig { max_batch: 1, max_new_tokens: 64, temperature: 0.0, ..Default::default() };
        let mut b = Batcher::new(backend, cfg, Arc::new(Metrics::new()));
        // Find what greedy generates first, then use it as the stop token.
        let mut probe = mk_batcher(1, 4);
        probe.submit(Request::new(0, vec![1, 2], 1));
        let first = probe.run_to_completion()[0].tokens[0];
        let mut req = Request::new(1, vec![1, 2], 64);
        req.stop_token = Some(first);
        b.submit(req);
        let out = b.run_to_completion();
        assert_eq!(out[0].finish, FinishReason::Stop);
        assert_eq!(out[0].tokens.len(), 1);
    }

    #[test]
    fn context_limit_terminates() {
        let mut b = mk_batcher(1, 4);
        let long_prompt: Vec<usize> = (0..120).map(|i| (i % 250) + 1).collect();
        b.submit(Request::new(1, long_prompt, 1000));
        let out = b.run_to_completion();
        assert_eq!(out[0].finish, FinishReason::Context);
        // Positions 0..119 hold the prompt; forwards at 119..=127 each
        // produce one sampled token ⇒ 9 generated, all 128 positions used.
        assert_eq!(out[0].tokens.len(), 9);
    }
}
