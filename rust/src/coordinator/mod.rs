//! The L3 serving coordinator: admission queue + router, continuous
//! (iteration-level) dynamic batcher, threaded leader loop, and metrics.
//!
//! Two backends plug in underneath ([`backend::DecodeBackend`]): the
//! pure-Rust model (always available) and the PJRT/AOT runtime (the
//! production path — `artifacts/*.hlo.txt` compiled once, Python never on
//! the request path).

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;

pub use backend::{DecodeBackend, NativeBackend, PjrtBackend, SlotStep};
pub use batcher::Batcher;
pub use metrics::{Metrics, MetricsReport};
pub use request::{FinishReason, InFlight, Request, Response};
pub use server::{ResponseHandle, Server};
