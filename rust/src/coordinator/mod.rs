//! The L3 serving coordinator: admission queue + router, continuous
//! (iteration-level) dynamic batcher, threaded leader loop, and metrics.
//!
//! Two backends plug in underneath ([`backend::DecodeBackend`]): the
//! pure-Rust model (always available) and the PJRT/AOT runtime (the
//! production path — `artifacts/*.hlo.txt` compiled once, Python never on
//! the request path).
//!
//! ## Memory model: the paged KV pool
//!
//! The native backend keeps all KV state in one shared
//! [`crate::kvcache::BlockPool`] page arena (`ServeConfig::kv` selects
//! page size and pool size): each slot holds a page table that grows
//! lazily as its sequence extends and is reclaimed in full on
//! completion. Serving capacity is therefore a function of **pool
//! pages**, not `slots × max_seq` — admission is gated on free pages
//! against the request's *whole-lifetime* footprint (prompt + generation
//! budget, pre-claimed at admission so concurrent admissions cannot
//! jointly oversubscribe and decode growth never races the free list).
//! A request that does not fit yet *defers* (FIFO, counted in metrics)
//! until a completion reclaims pages; one that could never fit even an
//! empty pool finishes immediately with `FinishReason::Rejected`.
//!
//! Pages are **refcounted and shareable** (`KvConfig::prefix_cache`):
//! once a prompt is fully prefilled, its full pages are published to a
//! content-hash prefix index, and later admissions with the same prompt
//! head pin those pages instead of allocating — the prompt-aware gate
//! (`DecodeBackend::can_admit_prompt`) discounts them, so a mostly
//! cached prompt fits a pool a cold one would not. Shared pages are
//! immutable; a sequence that must write into one (the hit ended inside
//! it) diverges through a pre-claimed copy-on-write spare. Pages whose
//! last holder releases them park in a FIFO *cached* state, revivable
//! by the next hit and evictable under allocation pressure — so the
//! cache costs no reserved capacity.
//!
//! ## Scheduling: budgeted prefill, continuous decode, preemption
//!
//! Each batcher step runs two phases: (1) batched prefill across
//! prefilling slots under a **shared** `ServeConfig::prefill_budget`
//! token cap, round-robin so a tight budget still makes progress on
//! every prompt — bounding decode stall per step regardless of how many
//! prompts arrive at once; non-final prefill chunks skip the lm_head
//! GEMM (`want_logits = false`); (2) one decode token for every decoding
//! slot.
//!
//! When admission would defer and a decoding slot holds *strictly*
//! lower-priority work (`Request::priority`), the batcher **preempts**
//! it (`KvConfig::preempt`): spill mode copies the victim's KV to a
//! host arena and restores it bulk on resume; recompute mode drops the
//! KV and replays prompt + sampled tokens through prefill (resumed
//! replays never re-sample, so outputs stay bit-exact either way).
//! Victims resume from a FIFO queue that outranks fresh arrivals of
//! equal priority. [`metrics::Metrics`] reports prefill/decode token
//! splits, admission deferrals, preemptions/resumes, prefix-cache
//! hit rates, and the KV pool occupancy/churn snapshot.
//!
//! ## Observability
//!
//! [`metrics::Metrics`] is fixed-memory: latency distributions live in
//! [`crate::obs::hist::Histogram`] buckets, per-request lifecycle spans
//! ([`crate::obs::trace::SpanRecord`]) in a bounded ring, and per-step
//! phase timings (`sched/*` from the batcher, `model/*` from the forward
//! pass, `engine/*` from the GEMM counters) in a
//! [`crate::util::timer::PhaseTimer`]. The `bench-serve` CLI drives this
//! stack with seeded workloads ([`crate::obs::loadgen`]) and exports a
//! schema-versioned artifact ([`crate::obs::export`]).

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;

pub use backend::{DecodeBackend, NativeBackend, PjrtBackend, SlotStep};
pub use batcher::Batcher;
pub use metrics::{Metrics, MetricsReport};
pub use request::{FinishReason, InFlight, Request, Response};
pub use server::{ResponseHandle, Server};
