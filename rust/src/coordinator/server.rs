//! Threaded serving front-end: a leader thread runs the batcher loop; any
//! number of client threads submit requests through a channel and wait on
//! per-request response channels. This is the L3 event loop — requests
//! never touch Python.

use super::backend::DecodeBackend;
use super::batcher::Batcher;
use super::metrics::{Metrics, MetricsReport};
use super::request::{Request, Response};
use crate::config::ServeConfig;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

enum Msg {
    Submit(Request, Sender<Response>),
    Shutdown,
}

/// Handle for one in-flight request.
pub struct ResponseHandle {
    rx: Receiver<Response>,
}

impl ResponseHandle {
    /// Block until the generation finishes.
    pub fn wait(self) -> Response {
        self.rx.recv().expect("server dropped the response channel")
    }

    pub fn wait_timeout(&self, d: Duration) -> Option<Response> {
        self.rx.recv_timeout(d).ok()
    }
}

/// The serving coordinator.
pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    running: Arc<AtomicBool>,
}

impl Server {
    /// Spawn the leader loop over `backend`.
    pub fn start(backend: Box<dyn DecodeBackend>, cfg: ServeConfig) -> Server {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = channel::<Msg>();
        let running = Arc::new(AtomicBool::new(true));
        let m2 = metrics.clone();
        let r2 = running.clone();
        let window = Duration::from_micros(cfg.batch_window_us);
        let worker = std::thread::Builder::new()
            .name("codegemm-leader".into())
            .spawn(move || {
                let mut batcher = Batcher::new(backend, cfg, m2);
                let mut pending: Vec<(u64, Sender<Response>)> = Vec::new();
                loop {
                    // Pull every queued message; block briefly when idle so
                    // the loop does not spin.
                    let msg = if batcher.is_idle() {
                        match rx.recv_timeout(Duration::from_millis(50)) {
                            Ok(m) => Some(m),
                            Err(_) => None,
                        }
                    } else {
                        rx.try_recv().ok()
                    };
                    match msg {
                        Some(Msg::Submit(req, resp_tx)) => {
                            let id = req.id;
                            if batcher.submit(req) {
                                pending.push((id, resp_tx));
                            }
                            // Batch-forming window: give co-arriving
                            // requests a chance to join the same admission.
                            if !window.is_zero() {
                                let deadline = std::time::Instant::now() + window;
                                while let Ok(m) = rx.recv_timeout(
                                    deadline.saturating_duration_since(std::time::Instant::now()),
                                ) {
                                    match m {
                                        Msg::Submit(r, t) => {
                                            let id = r.id;
                                            if batcher.submit(r) {
                                                pending.push((id, t));
                                            }
                                        }
                                        Msg::Shutdown => {
                                            r2.store(false, Ordering::SeqCst);
                                            break;
                                        }
                                    }
                                }
                            }
                        }
                        Some(Msg::Shutdown) => {
                            r2.store(false, Ordering::SeqCst);
                        }
                        None => {}
                    }
                    batcher.step();
                    for resp in batcher.take_finished() {
                        if let Some(idx) = pending.iter().position(|(id, _)| *id == resp.id) {
                            let (_, tx) = pending.swap_remove(idx);
                            let _ = tx.send(resp);
                        }
                    }
                    if !r2.load(Ordering::SeqCst) && batcher.is_idle() {
                        break;
                    }
                }
            })
            .expect("spawn leader thread");
        Server { tx, worker: Some(worker), metrics, next_id: AtomicU64::new(1), running }
    }

    /// Submit a request; its `id` field is overwritten with a fresh id.
    pub fn submit(&self, mut req: Request) -> ResponseHandle {
        req.id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        self.tx.send(Msg::Submit(req, tx)).expect("leader thread gone");
        ResponseHandle { rx }
    }

    /// Convenience: submit text, wait for the generated text.
    pub fn generate_text(&self, prompt: &str, max_new_tokens: usize) -> Response {
        self.submit(Request::from_text(0, prompt, max_new_tokens)).wait()
    }

    pub fn metrics(&self) -> MetricsReport {
        self.metrics.report()
    }

    /// Record the kernel-profiler gauge bundle of a traced run into the
    /// metrics sink (callers drain `obs::prof` themselves — typically
    /// right before [`Server::shutdown`] — because the profiler's rings
    /// are process-global, not owned by the server).
    pub fn record_prof(&self, summary: crate::obs::prof::ProfSummary) {
        self.metrics.on_prof(summary);
    }

    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    /// Finish in-flight work and stop the leader thread.
    pub fn shutdown(mut self) -> MetricsReport {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        self.metrics.report()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::coordinator::backend::NativeBackend;
    use crate::model::{EngineKind, ModelWeights};

    fn start(max_batch: usize) -> Server {
        let w = ModelWeights::random(ModelConfig::tiny(), 3);
        let backend = Box::new(NativeBackend::new(&w, EngineKind::Dense, max_batch));
        let cfg = ServeConfig {
            max_batch,
            batch_window_us: 200,
            max_new_tokens: 8,
            temperature: 0.0,
            ..Default::default()
        };
        Server::start(backend, cfg)
    }

    #[test]
    fn serves_one_request() {
        let s = start(2);
        let resp = s.submit(Request::new(0, vec![1, 2, 3], 5)).wait();
        assert_eq!(resp.tokens.len(), 5);
        let m = s.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn serves_concurrent_clients() {
        let s = Arc::new(start(4));
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let s = s.clone();
                std::thread::spawn(move || {
                    let resp = s.submit(Request::new(0, vec![(i % 200) + 1, 2], 4)).wait();
                    assert_eq!(resp.tokens.len(), 4);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let m = s.metrics();
        assert_eq!(m.completed, 8);
        assert!(m.mean_batch > 1.0, "concurrent requests should batch (mean {})", m.mean_batch);
    }

    #[test]
    fn shutdown_completes_inflight() {
        let s = start(2);
        let h = s.submit(Request::new(0, vec![1], 6));
        let m = s.shutdown(); // must not drop the in-flight request
        assert_eq!(m.completed, 1);
        assert_eq!(h.wait().tokens.len(), 6);
    }
}
