//! Request/response types for the serving coordinator.

use std::time::Instant;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Prompt tokens (byte-level for the tiny model).
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
    pub temperature: f32,
    /// Stop generating at this token if produced (e.g. a newline byte).
    pub stop_token: Option<usize>,
    /// Scheduling priority. Higher wins: when the pool saturates, an
    /// admission candidate may preempt a decoding slot of *strictly*
    /// lower priority (so the default 0-vs-0 workload never preempts and
    /// behaves exactly as before preemption existed).
    pub priority: i32,
    /// Submit time — the anchor for queue-wait and client-visible TTFT
    /// attribution in the request's lifecycle span.
    pub created: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<usize>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            temperature: 0.0,
            stop_token: None,
            priority: 0,
            created: Instant::now(),
        }
    }

    /// Byte-level helper: prompt from text.
    pub fn from_text(id: u64, text: &str, max_new_tokens: usize) -> Request {
        Request::new(id, text.bytes().map(|b| b as usize).collect(), max_new_tokens)
    }

    pub fn with_priority(mut self, priority: i32) -> Request {
        self.priority = priority;
        self
    }
}

/// Why a sequence stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens`.
    Length,
    /// Produced the stop token.
    Stop,
    /// Prompt + generation hit the model context limit.
    Context,
    /// The request's worst-case KV footprint exceeds the whole pool — it
    /// could never be admitted, so it is rejected (empty generation)
    /// instead of deferring forever and head-of-line blocking the queue.
    Rejected,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    pub finish: FinishReason,
    /// Time from submit to first generated token (seconds).
    pub ttft_s: f64,
    /// Total time from submit to completion (seconds).
    pub latency_s: f64,
    /// Decode throughput for this request (generated tokens / decode time).
    pub tok_per_s: f64,
}

impl Response {
    /// Byte-level helper: generated tokens as (lossy) text.
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.tokens.iter().map(|&t| t as u8).collect::<Vec<u8>>()).into_owned()
    }
}

/// In-flight request state tracked by the batcher.
#[derive(Debug)]
pub struct InFlight {
    pub req: Request,
    /// Admission time (when the request won a slot); queue wait is
    /// `admitted - req.created`.
    pub admitted: Instant,
    pub first_token: Option<Instant>,
    /// When the final prompt chunk was consumed (prefill attribution).
    pub prefill_done: Option<Instant>,
    /// Scheduler steps that fed prompt tokens (> 1 ⇒ the shared prefill
    /// budget split this prompt across steps).
    pub prefill_chunks: u32,
    /// Tokens generated so far.
    pub generated: Vec<usize>,
    /// Next feed index still to prefill (== feed().len() ⇒ decoding).
    pub prefill_idx: usize,
    /// Current sequence position in the KV cache.
    pub pos: usize,
    /// Recompute-mode resume: the exact token stream to replay through
    /// prefill — the prompt plus every already-sampled token except the
    /// last (which becomes the next decode input). `None` for ordinary
    /// prefill and spill-mode resume.
    pub replay: Option<Vec<usize>>,
    /// Times this request was swapped out of a slot.
    pub preemptions: u32,
    /// Prompt tokens served from pinned prefix-cache pages instead of
    /// prefill at (re-)admission.
    pub prefix_hit_tokens: usize,
}

impl InFlight {
    pub fn new(req: Request) -> InFlight {
        InFlight {
            req,
            admitted: Instant::now(),
            first_token: None,
            prefill_done: None,
            prefill_chunks: 0,
            generated: Vec::new(),
            prefill_idx: 0,
            pos: 0,
            replay: None,
            preemptions: 0,
            prefix_hit_tokens: 0,
        }
    }

    /// The token stream prefill consumes: the replay stream while
    /// resuming a recompute-mode preemption, the prompt otherwise.
    pub fn feed(&self) -> &[usize] {
        self.replay.as_deref().unwrap_or(&self.req.prompt)
    }

    pub fn is_prefilling(&self) -> bool {
        self.prefill_idx < self.feed().len()
    }

    /// The token to feed next (feed stream during prefill, last generated
    /// after).
    pub fn next_input(&self) -> usize {
        if self.is_prefilling() {
            self.feed()[self.prefill_idx]
        } else {
            *self.generated.last().expect("decode phase implies a generated token or last prompt token")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_roundtrip() {
        let r = Request::from_text(1, "hi", 4);
        assert_eq!(r.prompt, vec![104, 105]);
        let resp = Response {
            id: 1,
            tokens: vec![104, 105],
            finish: FinishReason::Length,
            ttft_s: 0.0,
            latency_s: 0.0,
            tok_per_s: 0.0,
        };
        assert_eq!(resp.text(), "hi");
    }

    #[test]
    fn inflight_phases() {
        let mut f = InFlight::new(Request::new(1, vec![10, 11], 3));
        assert!(f.is_prefilling());
        assert_eq!(f.next_input(), 10);
        f.prefill_idx = 2;
        f.generated.push(42);
        assert!(!f.is_prefilling());
        assert_eq!(f.next_input(), 42);
    }
}
