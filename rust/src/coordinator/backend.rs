//! Decode backends the coordinator can drive.
//!
//! - [`NativeBackend`] — the pure-Rust `LlamaModel` (any `EngineKind`),
//!   always available; used for tests and CPU-reference serving. Its KV
//!   state lives in one shared [`BlockPool`] page arena: every slot holds
//!   a page table ([`SeqKv`]) that grows lazily during prefill/decode and
//!   is reclaimed in full on [`DecodeBackend::reset_slot`], so pool pages
//!   — not `slots × max_seq` — bound KV memory. The backend reports
//!   occupancy through [`DecodeBackend::kv_stats`] and gates admission
//!   through [`DecodeBackend::can_admit`].
//! - [`PjrtBackend`] — the AOT path: `artifacts/*.hlo.txt` compiled on the
//!   PJRT CPU client (`crate::runtime`), the production configuration
//!   (device-resident KV literals; no pool).
//!
//! Both expose slot-indexed single-token stepping; the batcher composes
//! continuous batches out of per-slot steps (batched chunked prefill
//! under a shared token budget + one decode token per decoding slot).

use crate::config::{KvConfig, ParallelConfig};
use crate::gemm::{Counters, KernelSel};
use crate::kvcache::{BlockPool, KvStats, PagedKv, SeqKv, SpilledKv};
use crate::model::{EngineKind, LlamaModel, ModelWeights};
use crate::runtime::ModelRuntime;
use crate::util::threadpool::ThreadPool;
use crate::util::timer::PhaseTimer;
use anyhow::{bail, Result};
use std::sync::Arc;

/// One slot's work item for a step.
#[derive(Clone, Copy, Debug)]
pub struct SlotStep {
    pub slot: usize,
    pub token: usize,
    pub pos: usize,
}

/// A batched single-token decode backend with `max_batch` persistent slots.
pub trait DecodeBackend: Send {
    fn max_batch(&self) -> usize;
    fn max_seq(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Advance the given slots by one token each. Returns one logits
    /// vector (len `vocab`) per entry of `steps`, in order.
    fn step(&mut self, steps: &[SlotStep]) -> Result<Vec<Vec<f32>>>;
    /// Prefill `tokens` (occupying positions `pos .. pos + tokens.len()`)
    /// into `slot`. When `want_logits` is true, returns the logits after
    /// the final token; when false (this chunk is not the end of the
    /// prompt, so the scheduler would discard them) the backend may skip
    /// the lm_head GEMM entirely and return `None`. The default steps
    /// token-by-token; backends with a batched forward (`NativeBackend` →
    /// `LlamaModel::forward_batch_logits`) override it so the whole chunk
    /// runs as true `m_batch = tokens.len()` GEMMs.
    fn prefill(
        &mut self,
        slot: usize,
        tokens: &[usize],
        pos: usize,
        want_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        if tokens.is_empty() {
            bail!("prefill needs at least one token");
        }
        let mut last = Vec::new();
        for (i, &token) in tokens.iter().enumerate() {
            last = self
                .step(&[SlotStep { slot, token, pos: pos + i }])?
                .pop()
                .expect("one logits vector per step");
        }
        Ok(if want_logits { Some(last) } else { None })
    }
    /// Recycle a slot for a new sequence.
    fn reset_slot(&mut self, slot: usize);
    /// Can a request whose sequence may occupy up to `max_tokens`
    /// positions (prompt + generation budget, clamped to the context
    /// window by pool-backed backends) be admitted right now? Pool-backed
    /// backends check free pages against that *whole-lifetime* bound, so
    /// an admitted sequence can never exhaust the pool mid-decode;
    /// backends without a pool always accept — slot availability is then
    /// the only bound.
    fn can_admit(&self, max_tokens: usize) -> bool {
        let _ = max_tokens;
        true
    }
    /// Could a request of `max_tokens` lifetime positions fit an *empty*
    /// pool? `false` means it can never be admitted (its page demand
    /// exceeds the whole pool) and must be rejected rather than deferred
    /// forever. Backends without a pool always say yes.
    fn can_ever_admit(&self, max_tokens: usize) -> bool {
        let _ = max_tokens;
        true
    }
    /// Reserve KV capacity for a freshly admitted request in `slot`
    /// (called right after [`Self::reset_slot`] at admission, with the
    /// same `max_tokens` bound given to [`Self::can_admit`]). Pool-backed
    /// backends pre-claim the sequence's whole-lifetime pages so that
    /// (a) further `can_admit` checks *within the same scheduler step*
    /// see the reduced free count — without this, several admissions
    /// could jointly pass the gate — and (b) decode growth never touches
    /// an exhausted free list. No-op default for backends without a pool.
    fn reserve(&mut self, slot: usize, max_tokens: usize) {
        let _ = (slot, max_tokens);
    }
    /// Prompt-aware admission gate: like [`Self::can_admit`], but a
    /// prefix-caching backend discounts the pages the prompt can pin
    /// from the index instead of allocating — so a request whose prompt
    /// is mostly cached fits a pool a cold request would not. Default:
    /// the prompt changes nothing.
    fn can_admit_prompt(&self, prompt: &[usize], max_tokens: usize) -> bool {
        let _ = prompt;
        self.can_admit(max_tokens)
    }
    /// [`Self::reserve`] with prefix-cache pinning: pins the prompt's
    /// cached full pages (plus a pre-claimed copy-on-write spare when the
    /// sequence will write into a pinned page) and claims the rest.
    /// Returns the number of prompt positions already served by pinned
    /// pages — the caller starts prefill at that index instead of 0.
    /// Default: plain reserve, nothing matched.
    fn reserve_with_prefix(&mut self, slot: usize, prompt: &[usize], max_tokens: usize) -> usize {
        let _ = prompt;
        self.reserve(slot, max_tokens);
        0
    }
    /// Register `slot`'s full prompt pages in the prefix index once its
    /// prompt is completely prefilled (they are immutable from then on —
    /// prompt positions are never rewritten). No-op default.
    fn publish_prefix(&mut self, slot: usize, tokens: &[usize]) {
        let _ = (slot, tokens);
    }
    /// Swap `slot`'s KV state out to host memory and release its pages
    /// (preemption). `None` means the backend cannot spill — the batcher
    /// falls back to recompute-from-prompt. The slot still needs
    /// [`Self::reset_slot`] semantics afterwards only on the fallback
    /// path; a successful spill leaves the slot empty.
    fn spill(&mut self, slot: usize) -> Option<SpilledKv> {
        let _ = slot;
        None
    }
    /// Re-admit a spilled sequence into `slot`: claim its whole-lifetime
    /// pages again (same `max_tokens` bound as admission) and bulk-copy
    /// the spilled contents back. `false` (claiming nothing) when the
    /// pool cannot hold it yet.
    fn restore(&mut self, slot: usize, spill: &SpilledKv, max_tokens: usize) -> bool {
        let _ = (slot, spill, max_tokens);
        false
    }
    /// KV-pool occupancy snapshot (`None` for backends without a pool).
    fn kv_stats(&self) -> Option<KvStats> {
        None
    }
    /// Cumulative GEMM work/traffic counters across the backend's model
    /// (`None` when the backend has no engine-level accounting, e.g. the
    /// compiled PJRT path). Gauge semantics: counters only grow, so the
    /// latest snapshot carries the whole serving history — the metrics
    /// report derives the build share and the fused-projection fanout
    /// from it.
    fn engine_counters(&self) -> Option<Counters> {
        None
    }
    /// Cumulative model-forward phase attribution (`model/gemm`,
    /// `model/attention`, `model/lm_head` seconds; `None` when the
    /// backend has no per-phase instrumentation, e.g. the compiled PJRT
    /// path). Gauge semantics: the timer accumulates over the model's
    /// whole life, so the latest snapshot carries the history.
    fn phases(&self) -> Option<PhaseTimer> {
        None
    }
    /// The CodeGEMM kernel selection (implementation + lane width) the
    /// backend's engines dispatch to, resolved once at construction
    /// against the host CPU and the `CODEGEMM_KERNEL` override. `None`
    /// when the backend has no CodeGEMM kernel layer (compiled PJRT
    /// path, or a non-CodeGEMM `EngineKind`). Surfaces in the metrics
    /// report and the `BENCH_<n>.json` gauges.
    fn kernel_sel(&self) -> Option<KernelSel> {
        None
    }
    /// High-water footprint of the model's shared engine scratch, split
    /// by buffer (`buf`, `buf2`, `book`, `book2` bytes) — feeds the
    /// `obs::roofline::FootprintAudit` working-set gauge. `None` when
    /// the backend has no host-side scratch (compiled PJRT path). Gauge
    /// semantics: capacities only grow, so the latest snapshot is the
    /// serving high-water mark.
    fn scratch_parts(&self) -> Option<(usize, usize, usize, usize)> {
        None
    }
    fn label(&self) -> String;
}

/// Pure-Rust backend: one `LlamaModel`, one shared KV page pool, one
/// page table per slot.
pub struct NativeBackend {
    model: LlamaModel,
    kv_pool: BlockPool,
    seqs: Vec<SeqKv>,
    /// Resolved kernel dispatch of the `EngineKind` the model was built
    /// with (`None` for non-CodeGEMM kinds) — fixed at construction.
    kernel: Option<KernelSel>,
    /// Prefix sharing toggle (from `KvConfig::prefix_cache`).
    prefix_cache: bool,
}

/// What admission's prefix consultation resolved for one prompt.
#[derive(Clone, Copy, Debug, Default)]
struct PrefixPlan {
    /// Index pages to pin (head of the page table).
    pin: usize,
    /// How many of those are currently cached — pinning them shrinks the
    /// allocatable set, so the admission gate subtracts them (a
    /// conservative upper bound when the match is clamped).
    cached_pins: usize,
    /// Prompt positions the pins serve; prefill starts here. Capped at
    /// `min(prompt, max_seq) - 1` so at least the final prompt position
    /// is recomputed — its logits feed the first sample.
    matched: usize,
    /// `matched` ends inside the last pinned page, so the sequence's
    /// recompute will write into it: pre-claim the copy-on-write spare.
    cow: bool,
}

impl NativeBackend {
    /// Default paging: page size from `KvConfig::default()`, pool sized
    /// to the same total capacity `max_batch` contiguous caches would
    /// hold (so the default changes layout, not memory bounds).
    pub fn new(weights: &ModelWeights, kind: EngineKind, max_batch: usize) -> NativeBackend {
        NativeBackend::with_kv(weights, kind, max_batch, &KvConfig::default())
    }

    /// Explicit paged-KV configuration (page size + pool pages — the
    /// serving-capacity knob: a pool smaller than `max_batch × max_seq`
    /// oversubscribes slots and lets the batcher admit on free pages).
    pub fn with_kv(
        weights: &ModelWeights,
        kind: EngineKind,
        max_batch: usize,
        kv: &KvConfig,
    ) -> NativeBackend {
        NativeBackend::with_kv_fused(weights, kind, max_batch, kv, true)
    }

    /// [`Self::with_kv`] with the fused-projection schedule explicit —
    /// the serial backend construction (no worker pool spawned), still
    /// honoring `ParallelConfig::fused_projections`.
    pub fn with_kv_fused(
        weights: &ModelWeights,
        kind: EngineKind,
        max_batch: usize,
        kv: &KvConfig,
        fused_projections: bool,
    ) -> NativeBackend {
        let sel = kind.kernel_sel();
        let model = LlamaModel::load_with_options(weights, kind, None, fused_projections);
        NativeBackend::assemble(model, max_batch, kv, sel)
    }

    /// Sharded-model backend: every linear of every step fans out across
    /// `pool` (`crate::parallel`), so the batcher's step latency scales
    /// with the worker count instead of a single core. Falls back to the
    /// serial model when `par` resolves to one shard.
    pub fn new_parallel(
        weights: &ModelWeights,
        kind: EngineKind,
        max_batch: usize,
        par: &ParallelConfig,
        pool: Arc<ThreadPool>,
    ) -> NativeBackend {
        NativeBackend::new_parallel_kv(weights, kind, max_batch, par, pool, &KvConfig::default())
    }

    /// Sharded model + explicit paged-KV configuration.
    pub fn new_parallel_kv(
        weights: &ModelWeights,
        kind: EngineKind,
        max_batch: usize,
        par: &ParallelConfig,
        pool: Arc<ThreadPool>,
        kv: &KvConfig,
    ) -> NativeBackend {
        if par.is_serial() {
            // Serial shard plan, but the fused-projection toggle (gated
            // by the private-table baseline) still applies — it is
            // orthogonal to sharding.
            return NativeBackend::with_kv_fused(
                weights,
                kind,
                max_batch,
                kv,
                par.fused_projections_effective(),
            );
        }
        let sel = kind.kernel_sel();
        let model = LlamaModel::load_parallel(weights, kind, None, par, pool);
        NativeBackend::assemble(model, max_batch, kv, sel)
    }

    fn assemble(
        model: LlamaModel,
        max_batch: usize,
        kv: &KvConfig,
        kernel: Option<KernelSel>,
    ) -> NativeBackend {
        let kv_pool = BlockPool::for_model(&model.cfg, kv, max_batch);
        // Page tables pre-reserve their worst case so the decode hot loop
        // never reallocates them.
        let max_pages = kv_pool.layout().max_pages_per_seq();
        let seqs = (0..max_batch).map(|_| SeqKv::with_capacity(max_pages)).collect();
        NativeBackend { model, kv_pool, seqs, kernel, prefix_cache: kv.prefix_cache }
    }

    /// The shared page pool (tests and capacity planning).
    pub fn pool(&self) -> &BlockPool {
        &self.kv_pool
    }

    /// Pages a new request needs at admission: enough for its whole
    /// lifetime (`prompt + max_new` positions, clamped to the context
    /// window, which also caps the claim at one sequence's maximum).
    /// Claiming the full bound up front is what makes mid-decode pool
    /// exhaustion impossible for admitted sequences.
    fn admit_pages(&self, max_tokens: usize) -> usize {
        let l = self.kv_pool.layout();
        l.pages_for(max_tokens.min(l.max_seq))
    }

    /// Price a prompt against the prefix index. Deterministic between
    /// `can_admit_prompt` and `reserve_with_prefix` within one admission
    /// decision: nothing in between allocates, and releases/publishes
    /// only grow the match.
    fn prefix_plan(&self, prompt: &[usize]) -> PrefixPlan {
        let l = self.kv_pool.layout();
        // At least the final prompt position is always recomputed (its
        // logits produce the first sample), which also forces CoW — and
        // thus a private copy — on a fully page-aligned whole-prompt hit.
        let limit = prompt.len().min(l.max_seq).saturating_sub(1);
        if !self.prefix_cache || limit == 0 {
            return PrefixPlan::default();
        }
        let (avail, cached) = self.kv_pool.prefix_peek_detail(prompt);
        let matched = (avail * l.page_size).min(limit);
        if matched == 0 {
            return PrefixPlan::default();
        }
        let pin = l.pages_for(matched);
        PrefixPlan {
            pin,
            cached_pins: cached.min(pin),
            matched,
            cow: matched % l.page_size != 0,
        }
    }
}

impl DecodeBackend for NativeBackend {
    fn max_batch(&self) -> usize {
        self.seqs.len()
    }

    fn max_seq(&self) -> usize {
        self.model.cfg.max_seq
    }

    fn vocab(&self) -> usize {
        self.model.cfg.vocab
    }

    fn step(&mut self, steps: &[SlotStep]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(steps.len());
        for s in steps {
            if s.slot >= self.seqs.len() {
                bail!("slot {} out of range", s.slot);
            }
            let mut logits = vec![0f32; self.model.cfg.vocab];
            let mut kv = PagedKv::bind(&mut self.kv_pool, &mut self.seqs[s.slot]);
            self.model.forward_into(s.token, s.pos, &mut kv, &mut logits);
            out.push(logits);
        }
        Ok(out)
    }

    /// Whole-chunk prefill through `LlamaModel::forward_batch_logits`:
    /// one batched GEMM pass per layer instead of `tokens.len()` GEMV
    /// passes, so the Psumbook build amortizes across the prompt (paper
    /// Eq. 3); the lm_head GEMM runs only when `want_logits`.
    fn prefill(
        &mut self,
        slot: usize,
        tokens: &[usize],
        pos: usize,
        want_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        if slot >= self.seqs.len() {
            bail!("slot {slot} out of range");
        }
        if tokens.is_empty() {
            bail!("prefill needs at least one token");
        }
        let mut kv = PagedKv::bind(&mut self.kv_pool, &mut self.seqs[slot]);
        Ok(self.model.forward_batch_logits(tokens, pos, &mut kv, want_logits))
    }

    fn reset_slot(&mut self, slot: usize) {
        // Full reclamation: every page goes back to the free list.
        self.seqs[slot].release(&mut self.kv_pool);
    }

    fn can_admit(&self, max_tokens: usize) -> bool {
        self.kv_pool.free_pages() >= self.admit_pages(max_tokens)
    }

    fn can_ever_admit(&self, max_tokens: usize) -> bool {
        self.kv_pool.total_pages() >= self.admit_pages(max_tokens)
    }

    fn reserve(&mut self, slot: usize, max_tokens: usize) {
        let need = self.admit_pages(max_tokens);
        let ok = self.seqs[slot].claim(&mut self.kv_pool, need);
        debug_assert!(ok, "reserve after can_admit cannot fail");
    }

    fn can_admit_prompt(&self, prompt: &[usize], max_tokens: usize) -> bool {
        let plan = self.prefix_plan(prompt);
        if plan.pin == 0 {
            return self.can_admit(max_tokens);
        }
        // Pinned pages are not allocated — but pinning a *cached* page
        // removes it from the allocatable set, so subtract those.
        let need = self.admit_pages(max_tokens) - plan.pin + plan.cow as usize;
        self.kv_pool.free_pages() - plan.cached_pins >= need
    }

    fn reserve_with_prefix(&mut self, slot: usize, prompt: &[usize], max_tokens: usize) -> usize {
        if !self.prefix_cache {
            self.reserve(slot, max_tokens);
            return 0;
        }
        let plan = self.prefix_plan(prompt);
        // Always consult the index (a planned non-match passes
        // `max_pages = 0`) so hit/miss counters see every admission.
        let pinned = self.kv_pool.prefix_acquire(prompt, plan.pin);
        debug_assert_eq!(pinned.len(), plan.pin, "peek and acquire disagree");
        if !pinned.is_empty() {
            self.seqs[slot].set_prefix(&pinned, plan.matched);
            if plan.cow {
                let ok = self.seqs[slot].claim_cow_spare(&mut self.kv_pool);
                debug_assert!(ok, "cow-spare claim after can_admit_prompt cannot fail");
            }
        }
        let need = self.admit_pages(max_tokens);
        let ok = self.seqs[slot].claim(&mut self.kv_pool, need);
        debug_assert!(ok, "reserve after can_admit_prompt cannot fail");
        plan.matched
    }

    fn publish_prefix(&mut self, slot: usize, tokens: &[usize]) {
        if !self.prefix_cache {
            return;
        }
        let ps = self.kv_pool.layout().page_size;
        let full = tokens.len() / ps;
        if full == 0 {
            return;
        }
        let seq = &self.seqs[slot];
        debug_assert!(seq.pages().len() >= full, "publishing pages the slot does not hold");
        let pages = seq.pages()[..full].to_vec();
        self.kv_pool.publish_prefix(&tokens[..full * ps], &pages);
    }

    fn spill(&mut self, slot: usize) -> Option<SpilledKv> {
        let l = self.kv_pool.layout();
        let len = self.seqs[slot].len();
        let n = l.pages_for(len);
        // Snapshot the *coded* page bytes verbatim — never decode and
        // re-encode, so the resumed sequence is bit-identical in every
        // dtype (and an int8 spill costs ~3.8× less host memory).
        let data = self.kv_pool.export_pages(&self.seqs[slot].pages()[..n]);
        // Copy everything first, release last: a panic mid-copy leaves
        // the pages held, so the batcher's recompute fallback can still
        // `reset_slot` cleanly.
        self.seqs[slot].release(&mut self.kv_pool);
        Some(SpilledKv { len, data })
    }

    fn restore(&mut self, slot: usize, spill: &SpilledKv, max_tokens: usize) -> bool {
        let need = self.admit_pages(max_tokens);
        if self.kv_pool.free_pages() < need {
            return false;
        }
        debug_assert!(self.seqs[slot].pages().is_empty(), "restore into an occupied slot");
        let ok = self.seqs[slot].claim(&mut self.kv_pool, need);
        debug_assert!(ok, "claim after the free-page check cannot fail");
        let n = self.kv_pool.layout().pages_for(spill.len);
        for i in 0..n {
            let page = self.seqs[slot].pages()[i];
            self.kv_pool.import_page(page, &spill.data, i);
        }
        self.seqs[slot].set_len(spill.len);
        true
    }

    fn kv_stats(&self) -> Option<KvStats> {
        let layout = self.kv_pool.layout();
        Some(KvStats {
            pool: self.kv_pool.stats(),
            slot_bytes: self.seqs.iter().map(|s| s.n_pages() * layout.page_bytes()).collect(),
            slot_bytes_used: self.seqs.iter().map(|s| layout.bytes_for(s.len())).collect(),
        })
    }

    fn engine_counters(&self) -> Option<Counters> {
        Some(self.model.total_counters())
    }

    fn phases(&self) -> Option<PhaseTimer> {
        Some(self.model.phases().clone())
    }

    fn kernel_sel(&self) -> Option<KernelSel> {
        self.kernel
    }

    fn scratch_parts(&self) -> Option<(usize, usize, usize, usize)> {
        Some(self.model.scratch_parts())
    }

    fn label(&self) -> String {
        format!("native/{}", self.model.kind_label)
    }
}

/// AOT/PJRT backend: one compiled decode-step executable at the serving
/// batch size, full-batch stepping with padded idle slots.
///
/// Idle-slot padding is safe: a padded slot re-writes K/V at its own
/// current position, and any position a *future* sequence will read is
/// first overwritten by that sequence's prefill.
pub struct PjrtBackend {
    rt: ModelRuntime,
    batch: usize,
    /// KV state lives inside PJRT literals between steps — no host
    /// round-trip on the hot path (§Perf).
    kv_k: xla::Literal,
    kv_v: xla::Literal,
    /// Per-slot current length (for idle-slot padding positions).
    slot_len: Vec<usize>,
}

impl PjrtBackend {
    /// Use the largest compiled batch bucket in the artifacts.
    pub fn new(rt: ModelRuntime) -> PjrtBackend {
        let batch = rt.max_batch();
        PjrtBackend::with_batch(rt, batch)
    }

    /// Use a specific compiled batch bucket.
    pub fn with_batch(rt: ModelRuntime, batch: usize) -> PjrtBackend {
        assert!(rt.batch_sizes().contains(&batch), "no artifact for batch {batch}");
        let (kv_k, kv_v) = rt.new_kv_literals(batch).expect("kv literals");
        PjrtBackend { rt, batch, kv_k, kv_v, slot_len: vec![0; batch] }
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.rt
    }
}

// SAFETY: same argument as `ModelRuntime`'s Send impl — the KV literals
// are owned exclusively by this struct, which is moved (never shared) to
// the leader thread; `Send` without `Sync` encodes exactly that.
unsafe impl Send for PjrtBackend {}

impl DecodeBackend for PjrtBackend {
    fn max_batch(&self) -> usize {
        self.batch
    }

    fn max_seq(&self) -> usize {
        self.rt.manifest.model.max_seq
    }

    fn vocab(&self) -> usize {
        self.rt.manifest.model.vocab
    }

    fn step(&mut self, steps: &[SlotStep]) -> Result<Vec<Vec<f32>>> {
        let vocab = self.vocab();
        let max_seq = self.max_seq();
        let mut tokens = vec![0i32; self.batch];
        let mut positions: Vec<i32> = (0..self.batch)
            .map(|s| (self.slot_len[s].min(max_seq - 1)) as i32)
            .collect();
        for s in steps {
            if s.slot >= self.batch {
                bail!("slot {} out of range", s.slot);
            }
            tokens[s.slot] = s.token as i32;
            positions[s.slot] = s.pos as i32;
        }
        let logits =
            self.rt.decode_step_lit(self.batch, &tokens, &positions, &mut self.kv_k, &mut self.kv_v)?;
        for s in steps {
            self.slot_len[s.slot] = s.pos + 1;
        }
        Ok(steps
            .iter()
            .map(|s| logits[s.slot * vocab..(s.slot + 1) * vocab].to_vec())
            .collect())
    }

    fn reset_slot(&mut self, slot: usize) {
        // Zeroing the lane is not required for correctness (a new
        // sequence's prefill overwrites every position before it is read,
        // and attention masks positions beyond `pos`); only the length
        // bookkeeping resets. This keeps slot recycling O(1) — no KV
        // round-trip through the host.
        self.slot_len[slot] = 0;
    }

    fn label(&self) -> String {
        format!("pjrt/{}-b{}", self.rt.manifest.engine, self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::stats;

    #[test]
    fn native_backend_slots_are_independent() {
        let w = ModelWeights::random(ModelConfig::tiny(), 11);
        let mut b = NativeBackend::new(&w, EngineKind::Dense, 2);
        // Feed different histories into slot 0 and 1, then the same token;
        // logits must differ (separate KV) …
        b.step(&[SlotStep { slot: 0, token: 1, pos: 0 }, SlotStep { slot: 1, token: 99, pos: 0 }]).unwrap();
        let out = b
            .step(&[SlotStep { slot: 0, token: 5, pos: 1 }, SlotStep { slot: 1, token: 5, pos: 1 }])
            .unwrap();
        assert!(stats::rel_l2(&out[0], &out[1]) > 1e-5);
        // … and resetting slot 1 then replaying slot 0's history converges.
        b.reset_slot(1);
        b.step(&[SlotStep { slot: 1, token: 1, pos: 0 }]).unwrap();
        let out2 = b.step(&[SlotStep { slot: 1, token: 5, pos: 1 }]).unwrap();
        assert!(stats::rel_l2(&out2[0], &out[0]) < 1e-6);
    }

    #[test]
    fn batched_prefill_matches_stepped_prefill() {
        let w = ModelWeights::random(ModelConfig::tiny(), 13);
        let prompt = [3usize, 7, 11, 19];
        let mut a = NativeBackend::new(&w, EngineKind::Dense, 1);
        let la = a.prefill(0, &prompt, 0, true).unwrap().expect("logits wanted");
        let mut b = NativeBackend::new(&w, EngineKind::Dense, 1);
        let mut lb = Vec::new();
        for (i, &t) in prompt.iter().enumerate() {
            lb = b.step(&[SlotStep { slot: 0, token: t, pos: i }]).unwrap().remove(0);
        }
        assert!(stats::rel_l2(&la, &lb) < 1e-6);
        // Decode after either prefill continues identically.
        let da = a.step(&[SlotStep { slot: 0, token: 42, pos: 4 }]).unwrap();
        let db = b.step(&[SlotStep { slot: 0, token: 42, pos: 4 }]).unwrap();
        assert!(stats::rel_l2(&da[0], &db[0]) < 1e-6);
    }

    #[test]
    fn prefill_without_logits_skips_them_but_fills_the_cache() {
        let w = ModelWeights::random(ModelConfig::tiny(), 13);
        let prompt = [3usize, 7, 11, 19];
        // Split prefill: first chunk wants no logits, second does.
        let mut a = NativeBackend::new(&w, EngineKind::Dense, 1);
        assert!(a.prefill(0, &prompt[..2], 0, false).unwrap().is_none());
        let la = a.prefill(0, &prompt[2..], 2, true).unwrap().unwrap();
        // Whole-prompt prefill for reference.
        let mut b = NativeBackend::new(&w, EngineKind::Dense, 1);
        let lb = b.prefill(0, &prompt, 0, true).unwrap().unwrap();
        assert!(stats::rel_l2(&la, &lb) < 1e-6);
    }

    #[test]
    fn pool_bounds_kv_bytes_not_slot_count() {
        use crate::config::KvConfig;
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(cfg.clone(), 13);
        // 8 slots over a pool of 8 pages of 16 tokens: total KV capacity
        // is 128 tokens — far below 8 × max_seq.
        let kv = KvConfig { page_size: 16, pool_pages: 8, ..KvConfig::default() };
        let mut b = NativeBackend::with_kv(&w, EngineKind::Dense, 8, &kv);
        // 4 short sequences: one page each.
        for slot in 0..4 {
            b.prefill(slot, &[1, 2, 3], 0, true).unwrap();
        }
        let stats = b.kv_stats().unwrap();
        assert_eq!(stats.pool.used_pages, 4);
        assert_eq!(stats.held_bytes(), 4 * stats.pool.page_bytes);
        let contiguous = 2 * cfg.n_layers * cfg.max_seq * cfg.kv_dim() * 4;
        assert!(stats.held_bytes() < 8 * contiguous, "paged must undercut N × max_seq");
        // Per-slot gauges: held >= used, empty slots hold nothing.
        for slot in 0..4 {
            assert!(stats.slot_bytes[slot] >= stats.slot_bytes_used[slot]);
            assert_eq!(stats.slot_bytes_used[slot], 2 * cfg.n_layers * 3 * cfg.kv_dim() * 4);
        }
        assert_eq!(stats.slot_bytes[7], 0);
        // Admission gate over whole-lifetime footprints: 4 pages free ⇒
        // a 3-token lifetime (1 page) fits, a 65-token one (5 pages)
        // does not — and a 200-token lifetime exceeds the whole 8-page
        // pool, so it can never be admitted.
        assert!(b.can_admit(3));
        assert!(!b.can_admit(65));
        // …but 65 tokens would fit an empty pool (5 of 8 pages).
        assert!(b.can_ever_admit(65));
        // Reclamation frees the gate again.
        for slot in 0..4 {
            b.reset_slot(slot);
        }
        let stats = b.kv_stats().unwrap();
        assert_eq!(stats.pool.free_pages, stats.pool.total_pages);
        assert!(b.can_admit(65));
    }

    #[test]
    fn prefix_reuse_matches_cold_prefill_bitwise() {
        let w = ModelWeights::random(ModelConfig::tiny(), 17);
        let kv = KvConfig { page_size: 16, pool_pages: 0, ..KvConfig::default() };
        let mut b = NativeBackend::with_kv(&w, EngineKind::Dense, 2, &kv);
        let prompt: Vec<usize> = (0..40).map(|i| (i * 7 + 3) % 50).collect();
        let lifetime = prompt.len() + 8;
        // Cold admission on slot 0: nothing cached yet.
        assert!(b.can_admit_prompt(&prompt, lifetime));
        assert_eq!(b.reserve_with_prefix(0, &prompt, lifetime), 0);
        let cold = b.prefill(0, &prompt, 0, true).unwrap().unwrap();
        b.publish_prefix(0, &prompt);
        assert_eq!(b.pool().stats().prefix_pages, 2, "two full 16-token pages of 40");
        // Warm admission on slot 1 pins both full pages and resumes
        // prefill at position 32.
        let matched = b.reserve_with_prefix(1, &prompt, lifetime);
        assert_eq!(matched, 32);
        let warm = b.prefill(1, &prompt[32..], 32, true).unwrap().unwrap();
        assert_eq!(cold, warm, "prefix reuse must be bit-exact");
        let s = b.pool().stats();
        assert_eq!(s.prefix_hits, 1);
        assert_eq!(s.prefix_misses, 1);
        assert_eq!(s.prefix_hit_tokens, 32);
        // Drain: zero used pages and refcounts; prefix pages stay cached
        // and allocatable.
        b.reset_slot(0);
        b.reset_slot(1);
        let s = b.pool().stats();
        assert_eq!(s.used_pages, 0);
        assert_eq!(s.live_refs, 0);
        assert_eq!(s.free_pages, s.total_pages);
        assert_eq!(s.cached_pages, 2);
    }

    #[test]
    fn spill_restore_roundtrip_is_bit_exact() {
        let w = ModelWeights::random(ModelConfig::tiny(), 19);
        let prompt = [3usize, 7, 11, 19, 23];
        let mut a = NativeBackend::new(&w, EngineKind::Dense, 1);
        a.reserve(0, 16);
        a.prefill(0, &prompt, 0, true).unwrap();
        let la = a.step(&[SlotStep { slot: 0, token: 42, pos: 5 }]).unwrap().remove(0);

        let mut b = NativeBackend::new(&w, EngineKind::Dense, 1);
        b.reserve(0, 16);
        b.prefill(0, &prompt, 0, true).unwrap();
        let spill = b.spill(0).expect("native backend spills");
        assert_eq!(spill.len, 5);
        assert_eq!(b.pool().used_pages(), 0, "spill releases the victim's pages");
        assert!(b.restore(0, &spill, 16));
        let lb = b.step(&[SlotStep { slot: 0, token: 42, pos: 5 }]).unwrap().remove(0);
        assert_eq!(la, lb, "spill/restore must be bit-exact");
    }

    #[test]
    fn parallel_backend_matches_serial_backend() {
        let w = ModelWeights::random(ModelConfig::tiny(), 11);
        let mut serial = NativeBackend::new(&w, EngineKind::Dense, 2);
        let par = ParallelConfig { num_threads: 3, shard_min_rows: 16, ..Default::default() };
        let pool = Arc::new(ThreadPool::new(3));
        let mut sharded = NativeBackend::new_parallel(&w, EngineKind::Dense, 2, &par, pool);
        assert!(sharded.label().contains("shard3"), "{}", sharded.label());
        let steps = [SlotStep { slot: 0, token: 9, pos: 0 }, SlotStep { slot: 1, token: 42, pos: 0 }];
        let (a, b) = (serial.step(&steps).unwrap(), sharded.step(&steps).unwrap());
        for (x, y) in a.iter().zip(&b) {
            assert!(stats::rel_l2(x, y) < 1e-5);
        }
    }

    #[test]
    fn parallel_backend_serial_config_falls_back() {
        let w = ModelWeights::random(ModelConfig::tiny(), 12);
        let pool = Arc::new(ThreadPool::new(1));
        let be =
            NativeBackend::new_parallel(&w, EngineKind::Dense, 1, &ParallelConfig::serial(), pool);
        assert_eq!(be.label(), "native/fp32");
    }

    #[test]
    fn step_results_follow_request_order() {
        let w = ModelWeights::random(ModelConfig::tiny(), 11);
        let mut b = NativeBackend::new(&w, EngineKind::Dense, 3);
        // Deliberately out-of-slot-order steps.
        let out = b
            .step(&[SlotStep { slot: 2, token: 7, pos: 0 }, SlotStep { slot: 0, token: 7, pos: 0 }])
            .unwrap();
        assert_eq!(out.len(), 2);
        // Same token, same (fresh) state ⇒ same logits regardless of slot.
        assert!(stats::rel_l2(&out[0], &out[1]) < 1e-6);
    }
}
