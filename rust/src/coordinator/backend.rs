//! Decode backends the coordinator can drive.
//!
//! - [`NativeBackend`] — the pure-Rust `LlamaModel` (any `EngineKind`),
//!   always available; used for tests and CPU-reference serving.
//! - [`PjrtBackend`] — the AOT path: `artifacts/*.hlo.txt` compiled on the
//!   PJRT CPU client (`crate::runtime`), the production configuration.
//!
//! Both expose slot-indexed single-token stepping; the batcher composes
//! continuous batches out of per-slot steps (token-level prefill, as in
//! Orca-style iteration-level scheduling).

use crate::config::ParallelConfig;
use crate::model::{EngineKind, KvCache, LlamaModel, ModelWeights};
use crate::runtime::ModelRuntime;
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Result};
use std::sync::Arc;

/// One slot's work item for a step.
#[derive(Clone, Copy, Debug)]
pub struct SlotStep {
    pub slot: usize,
    pub token: usize,
    pub pos: usize,
}

/// A batched single-token decode backend with `max_batch` persistent slots.
pub trait DecodeBackend: Send {
    fn max_batch(&self) -> usize;
    fn max_seq(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Advance the given slots by one token each. Returns one logits
    /// vector (len `vocab`) per entry of `steps`, in order.
    fn step(&mut self, steps: &[SlotStep]) -> Result<Vec<Vec<f32>>>;
    /// Prefill `tokens` (occupying positions `pos .. pos + tokens.len()`)
    /// into `slot`, returning the logits after the final token. The
    /// default steps token-by-token; backends with a batched forward
    /// (`NativeBackend` → `LlamaModel::forward_batch`) override it so the
    /// whole prompt runs as true `m_batch = tokens.len()` GEMMs.
    fn prefill(&mut self, slot: usize, tokens: &[usize], pos: usize) -> Result<Vec<f32>> {
        if tokens.is_empty() {
            bail!("prefill needs at least one token");
        }
        let mut last = Vec::new();
        for (i, &token) in tokens.iter().enumerate() {
            last = self
                .step(&[SlotStep { slot, token, pos: pos + i }])?
                .pop()
                .expect("one logits vector per step");
        }
        Ok(last)
    }
    /// Recycle a slot for a new sequence.
    fn reset_slot(&mut self, slot: usize);
    fn label(&self) -> String;
}

/// Pure-Rust backend: one `LlamaModel` + per-slot KV caches.
pub struct NativeBackend {
    model: LlamaModel,
    caches: Vec<KvCache>,
}

impl NativeBackend {
    pub fn new(weights: &ModelWeights, kind: EngineKind, max_batch: usize) -> NativeBackend {
        let model = LlamaModel::load(weights, kind, None);
        let caches = (0..max_batch).map(|_| model.new_cache()).collect();
        NativeBackend { model, caches }
    }

    /// Sharded-model backend: every linear of every step fans out across
    /// `pool` (`crate::parallel`), so the batcher's step latency scales
    /// with the worker count instead of a single core. Falls back to the
    /// serial model when `par` resolves to one shard.
    pub fn new_parallel(
        weights: &ModelWeights,
        kind: EngineKind,
        max_batch: usize,
        par: &ParallelConfig,
        pool: Arc<ThreadPool>,
    ) -> NativeBackend {
        if par.is_serial() {
            return NativeBackend::new(weights, kind, max_batch);
        }
        let model = LlamaModel::load_parallel(weights, kind, None, par, pool);
        let caches = (0..max_batch).map(|_| model.new_cache()).collect();
        NativeBackend { model, caches }
    }
}

impl DecodeBackend for NativeBackend {
    fn max_batch(&self) -> usize {
        self.caches.len()
    }

    fn max_seq(&self) -> usize {
        self.model.cfg.max_seq
    }

    fn vocab(&self) -> usize {
        self.model.cfg.vocab
    }

    fn step(&mut self, steps: &[SlotStep]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(steps.len());
        for s in steps {
            if s.slot >= self.caches.len() {
                bail!("slot {} out of range", s.slot);
            }
            let logits = self.model.forward(s.token, s.pos, &mut self.caches[s.slot]);
            out.push(logits);
        }
        Ok(out)
    }

    /// Whole-prompt prefill through `LlamaModel::forward_batch`: one
    /// batched GEMM pass per layer instead of `tokens.len()` GEMV passes,
    /// so the Psumbook build amortizes across the prompt (paper Eq. 3).
    fn prefill(&mut self, slot: usize, tokens: &[usize], pos: usize) -> Result<Vec<f32>> {
        if slot >= self.caches.len() {
            bail!("slot {slot} out of range");
        }
        if tokens.is_empty() {
            bail!("prefill needs at least one token");
        }
        Ok(self.model.forward_batch(tokens, pos, &mut self.caches[slot]))
    }

    fn reset_slot(&mut self, slot: usize) {
        self.caches[slot].clear();
    }

    fn label(&self) -> String {
        format!("native/{}", self.model.kind_label)
    }
}

/// AOT/PJRT backend: one compiled decode-step executable at the serving
/// batch size, full-batch stepping with padded idle slots.
///
/// Idle-slot padding is safe: a padded slot re-writes K/V at its own
/// current position, and any position a *future* sequence will read is
/// first overwritten by that sequence's prefill.
pub struct PjrtBackend {
    rt: ModelRuntime,
    batch: usize,
    /// KV state lives inside PJRT literals between steps — no host
    /// round-trip on the hot path (§Perf).
    kv_k: xla::Literal,
    kv_v: xla::Literal,
    /// Per-slot current length (for idle-slot padding positions).
    slot_len: Vec<usize>,
}

impl PjrtBackend {
    /// Use the largest compiled batch bucket in the artifacts.
    pub fn new(rt: ModelRuntime) -> PjrtBackend {
        let batch = rt.max_batch();
        PjrtBackend::with_batch(rt, batch)
    }

    /// Use a specific compiled batch bucket.
    pub fn with_batch(rt: ModelRuntime, batch: usize) -> PjrtBackend {
        assert!(rt.batch_sizes().contains(&batch), "no artifact for batch {batch}");
        let (kv_k, kv_v) = rt.new_kv_literals(batch).expect("kv literals");
        PjrtBackend { rt, batch, kv_k, kv_v, slot_len: vec![0; batch] }
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.rt
    }
}

// SAFETY: same argument as `ModelRuntime`'s Send impl — the KV literals
// are owned exclusively by this struct, which is moved (never shared) to
// the leader thread; `Send` without `Sync` encodes exactly that.
unsafe impl Send for PjrtBackend {}

impl DecodeBackend for PjrtBackend {
    fn max_batch(&self) -> usize {
        self.batch
    }

    fn max_seq(&self) -> usize {
        self.rt.manifest.model.max_seq
    }

    fn vocab(&self) -> usize {
        self.rt.manifest.model.vocab
    }

    fn step(&mut self, steps: &[SlotStep]) -> Result<Vec<Vec<f32>>> {
        let vocab = self.vocab();
        let max_seq = self.max_seq();
        let mut tokens = vec![0i32; self.batch];
        let mut positions: Vec<i32> = (0..self.batch)
            .map(|s| (self.slot_len[s].min(max_seq - 1)) as i32)
            .collect();
        for s in steps {
            if s.slot >= self.batch {
                bail!("slot {} out of range", s.slot);
            }
            tokens[s.slot] = s.token as i32;
            positions[s.slot] = s.pos as i32;
        }
        let logits =
            self.rt.decode_step_lit(self.batch, &tokens, &positions, &mut self.kv_k, &mut self.kv_v)?;
        for s in steps {
            self.slot_len[s.slot] = s.pos + 1;
        }
        Ok(steps
            .iter()
            .map(|s| logits[s.slot * vocab..(s.slot + 1) * vocab].to_vec())
            .collect())
    }

    fn reset_slot(&mut self, slot: usize) {
        // Zeroing the lane is not required for correctness (a new
        // sequence's prefill overwrites every position before it is read,
        // and attention masks positions beyond `pos`); only the length
        // bookkeeping resets. This keeps slot recycling O(1) — no KV
        // round-trip through the host.
        self.slot_len[slot] = 0;
    }

    fn label(&self) -> String {
        format!("pjrt/{}-b{}", self.rt.manifest.engine, self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::stats;

    #[test]
    fn native_backend_slots_are_independent() {
        let w = ModelWeights::random(ModelConfig::tiny(), 11);
        let mut b = NativeBackend::new(&w, EngineKind::Dense, 2);
        // Feed different histories into slot 0 and 1, then the same token;
        // logits must differ (separate KV) …
        b.step(&[SlotStep { slot: 0, token: 1, pos: 0 }, SlotStep { slot: 1, token: 99, pos: 0 }]).unwrap();
        let out = b
            .step(&[SlotStep { slot: 0, token: 5, pos: 1 }, SlotStep { slot: 1, token: 5, pos: 1 }])
            .unwrap();
        assert!(stats::rel_l2(&out[0], &out[1]) > 1e-5);
        // … and resetting slot 1 then replaying slot 0's history converges.
        b.reset_slot(1);
        b.step(&[SlotStep { slot: 1, token: 1, pos: 0 }]).unwrap();
        let out2 = b.step(&[SlotStep { slot: 1, token: 5, pos: 1 }]).unwrap();
        assert!(stats::rel_l2(&out2[0], &out[0]) < 1e-6);
    }

    #[test]
    fn batched_prefill_matches_stepped_prefill() {
        let w = ModelWeights::random(ModelConfig::tiny(), 13);
        let prompt = [3usize, 7, 11, 19];
        let mut a = NativeBackend::new(&w, EngineKind::Dense, 1);
        let la = a.prefill(0, &prompt, 0).unwrap();
        let mut b = NativeBackend::new(&w, EngineKind::Dense, 1);
        let mut lb = Vec::new();
        for (i, &t) in prompt.iter().enumerate() {
            lb = b.step(&[SlotStep { slot: 0, token: t, pos: i }]).unwrap().remove(0);
        }
        assert!(stats::rel_l2(&la, &lb) < 1e-6);
        // Decode after either prefill continues identically.
        let da = a.step(&[SlotStep { slot: 0, token: 42, pos: 4 }]).unwrap();
        let db = b.step(&[SlotStep { slot: 0, token: 42, pos: 4 }]).unwrap();
        assert!(stats::rel_l2(&da[0], &db[0]) < 1e-6);
    }

    #[test]
    fn parallel_backend_matches_serial_backend() {
        let w = ModelWeights::random(ModelConfig::tiny(), 11);
        let mut serial = NativeBackend::new(&w, EngineKind::Dense, 2);
        let par = ParallelConfig { num_threads: 3, shard_min_rows: 16, ..Default::default() };
        let pool = Arc::new(ThreadPool::new(3));
        let mut sharded = NativeBackend::new_parallel(&w, EngineKind::Dense, 2, &par, pool);
        assert!(sharded.label().contains("shard3"), "{}", sharded.label());
        let steps = [SlotStep { slot: 0, token: 9, pos: 0 }, SlotStep { slot: 1, token: 42, pos: 0 }];
        let (a, b) = (serial.step(&steps).unwrap(), sharded.step(&steps).unwrap());
        for (x, y) in a.iter().zip(&b) {
            assert!(stats::rel_l2(x, y) < 1e-5);
        }
    }

    #[test]
    fn parallel_backend_serial_config_falls_back() {
        let w = ModelWeights::random(ModelConfig::tiny(), 12);
        let pool = Arc::new(ThreadPool::new(1));
        let be =
            NativeBackend::new_parallel(&w, EngineKind::Dense, 1, &ParallelConfig::serial(), pool);
        assert_eq!(be.label(), "native/fp32");
    }

    #[test]
    fn step_results_follow_request_order() {
        let w = ModelWeights::random(ModelConfig::tiny(), 11);
        let mut b = NativeBackend::new(&w, EngineKind::Dense, 3);
        // Deliberately out-of-slot-order steps.
        let out = b
            .step(&[SlotStep { slot: 2, token: 7, pos: 0 }, SlotStep { slot: 0, token: 7, pos: 0 }])
            .unwrap();
        assert_eq!(out.len(), 2);
        // Same token, same (fresh) state ⇒ same logits regardless of slot.
        assert!(stats::rel_l2(&out[0], &out[1]) < 1e-6);
    }
}
