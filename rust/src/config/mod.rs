//! Configuration system: quantization hyperparameters, kernel tiling,
//! model presets, device presets, serving options — all JSON round-trip
//! capable and validated at construction.

pub mod serve;

pub use serve::{KvConfig, KvDtype, PreemptMode, ServeConfig};

use crate::util::json::Json;
use anyhow::{bail, Result};

/// Codebook quantization hyperparameters (paper §2.2, Figure 2):
/// `v` vector length, `m` number of additive codebooks, `b` bits per code
/// (codebook has `2^b` centroids), `g` normalization group size
/// (`g = -1` ⇒ row-wise normalization, i.e. one scale per row).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantConfig {
    pub v: usize,
    pub m: usize,
    pub b: usize,
    /// Group size; `None` encodes the paper's `g = -1` (row-wise).
    pub g: Option<usize>,
}

impl QuantConfig {
    /// `g <= 0` maps to row-wise normalization (paper's `g = -1`).
    pub fn new(v: usize, m: usize, b: usize, g: i64) -> Result<QuantConfig> {
        let cfg = QuantConfig { v, m, b, g: if g <= 0 { None } else { Some(g as usize) } };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.v == 0 || !self.v.is_power_of_two() || self.v > 64 {
            bail!("v must be a power of two in [1, 64], got {}", self.v);
        }
        if self.m == 0 || self.m > 8 {
            bail!("m must be in [1, 8], got {}", self.m);
        }
        if self.b == 0 || self.b > 16 {
            bail!("b must be in [1, 16], got {}", self.b);
        }
        if let Some(g) = self.g {
            if g < self.v {
                bail!("g ({g}) must be >= v ({})", self.v);
            }
            if g % self.v != 0 {
                bail!("g ({g}) must be a multiple of v ({})", self.v);
            }
        }
        Ok(())
    }

    /// Number of centroids per codebook.
    pub fn n_centroids(&self) -> usize {
        1usize << self.b
    }

    /// Effective group size for a row of length `k`.
    pub fn group_size(&self, k: usize) -> usize {
        self.g.unwrap_or(k)
    }

    /// Paper-style label, e.g. `m2v8g128` or `m1v4` for row-wise.
    pub fn label(&self) -> String {
        match self.g {
            Some(g) => format!("m{}v{}g{}", self.m, self.v, g),
            None => format!("m{}v{}", self.m, self.v),
        }
    }

    /// Parse labels like `m2v8g128`, `m1v4`, `m1v4g-1`.
    pub fn parse_label(s: &str) -> Result<QuantConfig> {
        let (with_b, s2) = match s.split_once('b') {
            // optional trailing bits spec like m1v4g128b8 — handled below
            _ => (None::<usize>, s),
        };
        let _ = with_b;
        let bytes = s2.as_bytes();
        if bytes.first() != Some(&b'm') {
            bail!("config label must start with 'm': {s}");
        }
        let mut m = 0usize;
        let mut v = 0usize;
        let mut g: i64 = -1;
        let mut b = 8usize;
        let mut i = 0;
        let parse_num = |bytes: &[u8], mut i: usize| -> (i64, usize) {
            let neg = bytes.get(i) == Some(&b'-');
            if neg {
                i += 1;
            }
            let mut x: i64 = 0;
            while i < bytes.len() && bytes[i].is_ascii_digit() {
                x = x * 10 + (bytes[i] - b'0') as i64;
                i += 1;
            }
            (if neg { -x } else { x }, i)
        };
        while i < bytes.len() {
            let key = bytes[i];
            let (val, ni) = parse_num(bytes, i + 1);
            i = ni;
            match key {
                b'm' => m = val as usize,
                b'v' => v = val as usize,
                b'g' => g = val,
                b'b' => b = val as usize,
                other => bail!("unknown key '{}' in label {s}", other as char),
            }
        }
        QuantConfig::new(v, m, b, g)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::from(self.v)),
            ("m", Json::from(self.m)),
            ("b", Json::from(self.b)),
            ("g", Json::from(self.g.map(|g| g as i64).unwrap_or(-1))),
        ])
    }

    pub fn from_json(j: &Json) -> Result<QuantConfig> {
        QuantConfig::new(j.req_usize("v")?, j.req_usize("m")?, j.req_usize("b")?, j.req_i64("g")?)
    }

    /// The paper's headline configurations.
    pub fn m1v4g128() -> QuantConfig {
        QuantConfig::new(4, 1, 8, 128).unwrap()
    }

    pub fn m2v8g128() -> QuantConfig {
        QuantConfig::new(8, 2, 8, 128).unwrap()
    }
}

/// Which gather/build kernel implementation the CodeGEMM engine runs
/// (`gemm::simd` dispatches on the resolved value; see
/// [`crate::gemm::simd::resolve`]). The `CODEGEMM_KERNEL` environment
/// variable (same spellings) overrides this at engine construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum KernelImpl {
    /// Pick the fastest available path (AVX2 when detected, else the
    /// portable unrolled kernels).
    #[default]
    Auto,
    /// Reference implementation — one row / batch column at a time.
    Scalar,
    /// Portable lane-parallel kernels (manual 8/16-wide unroll, no
    /// `std::arch`).
    Unrolled,
    /// Explicit AVX2 (`std::arch::x86_64`) kernels; downgrades to
    /// `Unrolled` when the host lacks AVX2.
    Avx2,
}

impl KernelImpl {
    pub fn parse(s: &str) -> Option<KernelImpl> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(KernelImpl::Auto),
            "scalar" => Some(KernelImpl::Scalar),
            "unrolled" => Some(KernelImpl::Unrolled),
            "avx2" => Some(KernelImpl::Avx2),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KernelImpl::Auto => "auto",
            KernelImpl::Scalar => "scalar",
            KernelImpl::Unrolled => "unrolled",
            KernelImpl::Avx2 => "avx2",
        }
    }
}

/// Kernel tiling parameters (paper §3: defaults t_w = 32, t_h = 2048)
/// plus the kernel-dispatch knobs added with the SIMD layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelConfig {
    pub tile_w: usize,
    pub tile_h: usize,
    /// Gather/build implementation (see [`KernelImpl`]).
    pub kernel_impl: KernelImpl,
    /// Requested SIMD lane width: `0` = auto (8), `1` = scalar, values
    /// are normalized to the supported widths {1, 8, 16} by
    /// [`KernelConfig::effective_lanes`]. Tiling depends only on this
    /// knob — never on `kernel_impl` — so engines configured for
    /// different impls tile identically and stay bit-comparable.
    pub simd_lanes: usize,
    /// Software-pipeline the shared-book schedule: overlap tile `t+1`'s
    /// Psumbook build with tile `t`'s gather (double-buffered book
    /// scratch). Bit-exact either way; default on.
    pub pipeline_tiles: bool,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            tile_w: 32,
            tile_h: 2048,
            kernel_impl: KernelImpl::Auto,
            simd_lanes: 0,
            pipeline_tiles: true,
        }
    }
}

impl KernelConfig {
    pub fn new(tile_w: usize, tile_h: usize) -> Result<KernelConfig> {
        if tile_w == 0 || tile_h == 0 {
            bail!("tile dims must be positive");
        }
        Ok(KernelConfig { tile_w, tile_h, ..KernelConfig::default() })
    }

    /// The lane width the SIMD gather kernels advance per step,
    /// normalized from the `simd_lanes` request: `0` (auto) and `2..=8`
    /// map to 8, `1` stays scalar, anything larger maps to 16.
    pub fn effective_lanes(&self) -> usize {
        match self.simd_lanes {
            0 => 8,
            1 => 1,
            2..=8 => 8,
            _ => 16,
        }
    }

    /// Clamp `tile_w` for a `(k, v)` layer: bounded by `k` and rounded
    /// down to the nearest multiple of both `v` and the active SIMD lane
    /// width (minimum one vector), so engine construction never panics
    /// on non-default shapes. When the lane-aligned width would be zero
    /// (tile smaller than one lane block), alignment falls back to the
    /// `v` multiple alone — the lane kernels handle any tile width; the
    /// alignment only keeps k-tile boundaries (and therefore the scale
    /// runs inside each tile) identical across lane configurations.
    /// `k` must be a positive multiple of `v` (every validated quantized
    /// layer guarantees this). Shared by the CodeGEMM and dequant
    /// engines so the rounding policy lives in one place.
    pub fn align_tile_w(&mut self, k: usize, v: usize) {
        // v and the lane width are both powers of two, so lcm = max.
        let lane_mult = v.max(self.effective_lanes());
        self.tile_w = self.tile_w.min(k);
        let lane_aligned = self.tile_w - self.tile_w % lane_mult;
        if lane_aligned > 0 {
            self.tile_w = lane_aligned;
        } else {
            self.tile_w -= self.tile_w % v;
            if self.tile_w == 0 {
                self.tile_w = v;
            }
        }
    }

    pub fn validate_for(&self, cfg: &QuantConfig, k: usize) -> Result<()> {
        if self.tile_w % cfg.v != 0 {
            bail!("tile_w ({}) must be a multiple of v ({})", self.tile_w, cfg.v);
        }
        if let Some(g) = cfg.g {
            // Group boundaries must not straddle a tile boundary mid-group
            // unless tiles divide groups evenly (either direction works).
            if g % self.tile_w != 0 && self.tile_w % g != 0 {
                bail!("tile_w ({}) and g ({g}) must divide one another", self.tile_w);
            }
        }
        if k % cfg.v != 0 {
            bail!("K ({k}) must be a multiple of v ({})", cfg.v);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tile_w", Json::from(self.tile_w)),
            ("tile_h", Json::from(self.tile_h)),
            ("kernel_impl", Json::Str(self.kernel_impl.as_str().to_string())),
            ("simd_lanes", Json::from(self.simd_lanes)),
            ("pipeline_tiles", Json::Bool(self.pipeline_tiles)),
        ])
    }

    /// Parse from JSON. `tile_w`/`tile_h` are required; the dispatch
    /// knobs are optional with defaults so configs written before the
    /// SIMD layer still parse.
    pub fn from_json(j: &Json) -> Result<KernelConfig> {
        let mut cfg = KernelConfig::new(j.req_usize("tile_w")?, j.req_usize("tile_h")?)?;
        if let Some(v) = j.get("kernel_impl") {
            let s = v.as_str().ok_or_else(|| anyhow::anyhow!("invalid field 'kernel_impl'"))?;
            cfg.kernel_impl = KernelImpl::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown kernel_impl '{s}'"))?;
        }
        if let Some(v) = j.get("simd_lanes") {
            cfg.simd_lanes =
                v.as_usize().ok_or_else(|| anyhow::anyhow!("invalid field 'simd_lanes'"))?;
        }
        if let Some(v) = j.get("pipeline_tiles") {
            cfg.pipeline_tiles =
                v.as_bool().ok_or_else(|| anyhow::anyhow!("invalid field 'pipeline_tiles'"))?;
        }
        Ok(cfg)
    }
}

/// Sharded-execution configuration (the `parallel` section): how GEMM
/// engines and the Llama forward pass fan out over the worker pool
/// (`crate::parallel`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Worker threads / maximum shards per linear (0 ⇒ available
    /// parallelism).
    pub num_threads: usize,
    /// Minimum rows (or reduction columns) per shard — layers too small
    /// to split at this granularity stay serial.
    pub shard_min_rows: usize,
    /// Shard the attention projections (Q/K/V column-parallel, O
    /// row-parallel).
    pub shard_attn: bool,
    /// Shard the MLP linears (gate/up column-parallel, down row-parallel).
    pub shard_mlp: bool,
    /// Shard the LM head (column-parallel).
    pub shard_lm_head: bool,
    /// Build one shared Psumbook per k-tile, gathered by every row shard
    /// (build once / gather many), instead of per-shard private books.
    /// Only affects CodeGEMM engines; outputs are bit-exact either way.
    /// `false` is the private-table measurement baseline and therefore
    /// also vetoes `fused_projections` (a fused group inherently shares
    /// its build).
    pub shared_psumbook: bool,
    /// Fuse the projections sharing one input activation (Q/K/V,
    /// gate/up) around a single Psumbook build per k-tile
    /// (`gemm::GemmGroup`) instead of building the book once per
    /// projection. Only affects CodeGEMM-class engines; outputs are
    /// bit-exact either way — per-layer build MACs drop ~3× (attention)
    /// / ~2× (MLP) at decode.
    pub fused_projections: bool,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            num_threads: 0,
            shard_min_rows: 64,
            shard_attn: true,
            shard_mlp: true,
            shard_lm_head: true,
            shared_psumbook: true,
            fused_projections: true,
        }
    }
}

impl ParallelConfig {
    /// Serial execution (single shard everywhere).
    pub fn serial() -> ParallelConfig {
        ParallelConfig { num_threads: 1, ..Default::default() }
    }

    /// All layer classes sharded across `n` threads.
    pub fn with_threads(n: usize) -> ParallelConfig {
        ParallelConfig { num_threads: n, ..Default::default() }
    }

    /// The fused-projection schedule actually in effect:
    /// `fused_projections` gated by `shared_psumbook` — the
    /// private-table baseline must veto fusion on *every* path,
    /// including serial and unsharded layer classes where no
    /// `GemmGroup`-level toggle would otherwise see `shared_psumbook`.
    pub fn fused_projections_effective(&self) -> bool {
        self.fused_projections && self.shared_psumbook
    }

    /// Resolved worker count (`num_threads`, or available parallelism
    /// when 0).
    pub fn effective_threads(&self) -> usize {
        if self.num_threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.num_threads
        }
    }

    /// True when this config cannot produce more than one shard.
    pub fn is_serial(&self) -> bool {
        self.effective_threads() <= 1
            || !(self.shard_attn || self.shard_mlp || self.shard_lm_head)
    }

    pub fn validate(&self) -> Result<()> {
        if self.shard_min_rows == 0 {
            bail!("shard_min_rows must be positive");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("num_threads", Json::from(self.num_threads)),
            ("shard_min_rows", Json::from(self.shard_min_rows)),
            ("shard_attn", Json::Bool(self.shard_attn)),
            ("shard_mlp", Json::Bool(self.shard_mlp)),
            ("shard_lm_head", Json::Bool(self.shard_lm_head)),
            ("shared_psumbook", Json::Bool(self.shared_psumbook)),
            ("fused_projections", Json::Bool(self.fused_projections)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ParallelConfig> {
        let d = ParallelConfig::default();
        let get_bool = |key: &str, dv: bool| -> Result<bool> {
            match j.get(key) {
                None => Ok(dv),
                Some(v) => {
                    v.as_bool().ok_or_else(|| anyhow::anyhow!("invalid bool field '{key}'"))
                }
            }
        };
        let cfg = ParallelConfig {
            num_threads: match j.get("num_threads") {
                None => d.num_threads,
                Some(v) => {
                    v.as_usize().ok_or_else(|| anyhow::anyhow!("invalid field 'num_threads'"))?
                }
            },
            shard_min_rows: match j.get("shard_min_rows") {
                None => d.shard_min_rows,
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("invalid field 'shard_min_rows'"))?,
            },
            shard_attn: get_bool("shard_attn", d.shard_attn)?,
            shard_mlp: get_bool("shard_mlp", d.shard_mlp)?,
            shard_lm_head: get_bool("shard_lm_head", d.shard_lm_head)?,
            shared_psumbook: get_bool("shared_psumbook", d.shared_psumbook)?,
            fused_projections: get_bool("fused_projections", d.fused_projections)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Model architecture configuration (mirrors `python/compile/model.py`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub rope_theta_milli: usize, // theta * 1000 kept integral for Eq/Hash
}

impl ModelConfig {
    /// The tiny byte-level model trained by `python/compile/train_tiny.py`
    /// and served end-to-end. Must match `TINY_CONFIG` there.
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            name: "tiny-llama".into(),
            vocab: 256,
            hidden: 128,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            ffn: 352,
            max_seq: 128,
            rope_theta_milli: 10_000_000,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    pub fn rope_theta(&self) -> f32 {
        self.rope_theta_milli as f32 / 1000.0
    }

    /// Parameter count (tied embeddings not assumed; lm_head separate).
    pub fn n_params(&self) -> usize {
        let d = self.hidden;
        let attn = d * d + 2 * d * self.kv_dim() + d * d;
        let mlp = 3 * d * self.ffn;
        let norms = 2 * d;
        self.vocab * d * 2 + self.n_layers * (attn + mlp + norms) + d
    }

    pub fn validate(&self) -> Result<()> {
        if self.hidden % self.n_heads != 0 {
            bail!("hidden ({}) must divide by n_heads ({})", self.hidden, self.n_heads);
        }
        if self.n_heads % self.n_kv_heads != 0 {
            bail!("n_heads must divide by n_kv_heads");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("vocab", Json::from(self.vocab)),
            ("hidden", Json::from(self.hidden)),
            ("n_layers", Json::from(self.n_layers)),
            ("n_heads", Json::from(self.n_heads)),
            ("n_kv_heads", Json::from(self.n_kv_heads)),
            ("ffn", Json::from(self.ffn)),
            ("max_seq", Json::from(self.max_seq)),
            ("rope_theta", Json::Num(self.rope_theta() as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        let cfg = ModelConfig {
            name: j.req_str("name")?.to_string(),
            vocab: j.req_usize("vocab")?,
            hidden: j.req_usize("hidden")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            n_kv_heads: j.req_usize("n_kv_heads")?,
            ffn: j.req_usize("ffn")?,
            max_seq: j.req_usize("max_seq")?,
            rope_theta_milli: (j.req_f64("rope_theta")? * 1000.0) as usize,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quant_config_validation() {
        assert!(QuantConfig::new(4, 1, 8, 128).is_ok());
        assert!(QuantConfig::new(3, 1, 8, 128).is_err()); // v not pow2
        assert!(QuantConfig::new(4, 0, 8, 128).is_err()); // m=0
        assert!(QuantConfig::new(4, 1, 0, 128).is_err()); // b=0
        assert!(QuantConfig::new(4, 1, 17, 128).is_err()); // b>16
        assert!(QuantConfig::new(8, 1, 8, 4).is_err()); // g < v
        assert!(QuantConfig::new(8, 1, 8, 20).is_err()); // g % v != 0
        assert!(QuantConfig::new(8, 1, 8, -1).is_ok()); // row-wise
    }

    #[test]
    fn labels_roundtrip() {
        for label in ["m2v8g128", "m1v4", "m3v16g32"] {
            let cfg = QuantConfig::parse_label(label).unwrap();
            assert_eq!(cfg.label(), label);
        }
        let cfg = QuantConfig::parse_label("m1v4b6g128").unwrap();
        assert_eq!(cfg.b, 6);
        assert!(QuantConfig::parse_label("x1v4").is_err());
    }

    #[test]
    fn headline_configs() {
        let a = QuantConfig::m1v4g128();
        assert_eq!((a.v, a.m, a.b, a.g), (4, 1, 8, Some(128)));
        let b = QuantConfig::m2v8g128();
        assert_eq!((b.v, b.m, b.b, b.g), (8, 2, 8, Some(128)));
    }

    #[test]
    fn json_roundtrip_quant() {
        let cfg = QuantConfig::new(8, 2, 8, -1).unwrap();
        let j = cfg.to_json();
        assert_eq!(QuantConfig::from_json(&j).unwrap(), cfg);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(QuantConfig::from_json(&parsed).unwrap(), cfg);
    }

    #[test]
    fn kernel_config_checks() {
        let kc = KernelConfig::default();
        assert_eq!((kc.tile_w, kc.tile_h), (32, 2048));
        let q = QuantConfig::new(8, 1, 8, 32).unwrap();
        assert!(kc.validate_for(&q, 4096).is_ok());
        let q2 = QuantConfig::new(64, 1, 8, -1).unwrap();
        assert!(kc.validate_for(&q2, 4096).is_err()); // tile_w % v != 0
        assert!(kc.validate_for(&q, 4095).is_err()); // K % v != 0
    }

    #[test]
    fn align_tile_w_rounds_down_and_floors_at_v() {
        let clamp = |tw: usize, k: usize, v: usize| {
            let mut kc = KernelConfig { tile_w: tw, tile_h: 8, ..Default::default() };
            kc.align_tile_w(k, v);
            kc.tile_w
        };
        assert_eq!(clamp(32, 4096, 8), 32); // already aligned
        assert_eq!(clamp(20, 4096, 8), 16); // round down
        assert_eq!(clamp(3, 4096, 8), 8); // floor at one vector
        assert_eq!(clamp(1000, 64, 8), 64); // clamp to k
        assert_eq!(clamp(32, 4096, 64), 64); // tile smaller than v
    }

    #[test]
    fn align_tile_w_honors_simd_lane_width() {
        let clamp = |tw: usize, lanes: usize, k: usize, v: usize| {
            let mut kc =
                KernelConfig { tile_w: tw, tile_h: 8, simd_lanes: lanes, ..Default::default() };
            kc.align_tile_w(k, v);
            kc.tile_w
        };
        // Default lanes (0 ⇒ 8): v=4 tiles round to 8-multiples.
        assert_eq!(clamp(20, 0, 4096, 4), 16);
        assert_eq!(clamp(24, 0, 4096, 4), 24);
        // 16 lanes: round down to the 16-multiple when one fits …
        assert_eq!(clamp(20, 16, 4096, 4), 16);
        assert_eq!(clamp(40, 16, 4096, 4), 32);
        // … and fall back to the v-multiple when it doesn't.
        assert_eq!(clamp(12, 16, 4096, 4), 12);
        // Scalar lanes leave the v rule unchanged.
        assert_eq!(clamp(20, 1, 4096, 4), 20);
        // k clamp still applies before lane alignment.
        assert_eq!(clamp(1000, 16, 24, 4), 16);
    }

    #[test]
    fn kernel_impl_parse_and_roundtrip() {
        for imp in [KernelImpl::Auto, KernelImpl::Scalar, KernelImpl::Unrolled, KernelImpl::Avx2] {
            assert_eq!(KernelImpl::parse(imp.as_str()), Some(imp));
        }
        assert_eq!(KernelImpl::parse(" AVX2 "), Some(KernelImpl::Avx2));
        assert_eq!(KernelImpl::parse("sse9"), None);

        let cfg = KernelConfig {
            tile_w: 64,
            tile_h: 128,
            kernel_impl: KernelImpl::Unrolled,
            simd_lanes: 16,
            pipeline_tiles: false,
        };
        let j = Json::parse(&cfg.to_json().to_string_pretty()).unwrap();
        assert_eq!(KernelConfig::from_json(&j).unwrap(), cfg);
        // Pre-SIMD artifacts (tile dims only) still parse, with defaults.
        let old = Json::parse(r#"{"tile_w": 16, "tile_h": 8}"#).unwrap();
        let parsed = KernelConfig::from_json(&old).unwrap();
        assert_eq!(parsed, KernelConfig { tile_w: 16, tile_h: 8, ..Default::default() });
        assert!(KernelConfig::from_json(
            &Json::parse(r#"{"tile_w": 16, "tile_h": 8, "kernel_impl": "sse9"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn effective_lanes_normalizes() {
        let with = |lanes| KernelConfig { simd_lanes: lanes, ..Default::default() };
        assert_eq!(with(0).effective_lanes(), 8);
        assert_eq!(with(1).effective_lanes(), 1);
        assert_eq!(with(4).effective_lanes(), 8);
        assert_eq!(with(8).effective_lanes(), 8);
        assert_eq!(with(16).effective_lanes(), 16);
        assert_eq!(with(99).effective_lanes(), 16);
    }

    #[test]
    fn model_config_tiny() {
        let m = ModelConfig::tiny();
        m.validate().unwrap();
        assert_eq!(m.head_dim(), 32);
        assert_eq!(m.kv_dim(), 64);
        assert!(m.n_params() > 100_000);
        let j = m.to_json();
        assert_eq!(ModelConfig::from_json(&j).unwrap(), m);
    }

    #[test]
    fn parallel_config_roundtrip_and_defaults() {
        let cfg = ParallelConfig {
            num_threads: 4,
            shard_min_rows: 32,
            shard_lm_head: false,
            shared_psumbook: false,
            ..Default::default()
        };
        cfg.validate().unwrap();
        let j = Json::parse(&cfg.to_json().to_string_pretty()).unwrap();
        assert_eq!(ParallelConfig::from_json(&j).unwrap(), cfg);
        // Missing fields fall back to defaults (older configs stay valid).
        let j = Json::parse(r#"{"num_threads": 2}"#).unwrap();
        let c = ParallelConfig::from_json(&j).unwrap();
        assert_eq!(c.num_threads, 2);
        assert_eq!(c.shard_min_rows, ParallelConfig::default().shard_min_rows);
        assert!(c.shard_attn && c.shard_mlp && c.shard_lm_head);
        assert!(c.shared_psumbook, "shared books are the default");
        assert!(c.fused_projections, "fused projection groups are the default");
        // The toggle round-trips off, too.
        let j = Json::parse(r#"{"fused_projections": false}"#).unwrap();
        assert!(!ParallelConfig::from_json(&j).unwrap().fused_projections);
        // Invalid values are rejected.
        let bad = Json::parse(r#"{"shard_min_rows": 0}"#).unwrap();
        assert!(ParallelConfig::from_json(&bad).is_err());
    }

    #[test]
    fn private_table_baseline_vetoes_fused_projections() {
        // shared_psumbook = false requests private per-tile tables
        // everywhere — a fused group inherently shares its build, so
        // the effective fused flag must drop on every path.
        let base = ParallelConfig::default();
        assert!(base.fused_projections_effective());
        let private = ParallelConfig { shared_psumbook: false, ..Default::default() };
        assert!(private.fused_projections, "raw toggle untouched");
        assert!(!private.fused_projections_effective(), "baseline must veto fusion");
        let unfused = ParallelConfig { fused_projections: false, ..Default::default() };
        assert!(!unfused.fused_projections_effective());
    }

    #[test]
    fn parallel_config_serial_detection() {
        assert!(ParallelConfig::serial().is_serial());
        assert!(!ParallelConfig::with_threads(4).is_serial());
        let off = ParallelConfig {
            num_threads: 4,
            shard_attn: false,
            shard_mlp: false,
            shard_lm_head: false,
            ..Default::default()
        };
        assert!(off.is_serial());
        assert_eq!(ParallelConfig::with_threads(3).effective_threads(), 3);
        assert!(ParallelConfig::default().effective_threads() >= 1);
    }

    #[test]
    fn group_size_effective() {
        let row = QuantConfig::new(4, 1, 8, -1).unwrap();
        assert_eq!(row.group_size(4096), 4096);
        let grp = QuantConfig::new(4, 1, 8, 128).unwrap();
        assert_eq!(grp.group_size(4096), 128);
    }
}
