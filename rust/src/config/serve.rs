//! Serving configuration for the L3 coordinator.

use super::ParallelConfig;
use crate::util::json::Json;
use anyhow::{bail, Result};

/// What the batcher does when admission would defer for lack of pool
/// pages but a lower-priority slot is mid-decode.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PreemptMode {
    /// Never preempt: pure admission deferral (the pre-preemption
    /// behavior).
    Off,
    /// Swap the victim's private pages to a host-side spill arena and
    /// bulk-copy them back on resume (host memory for compute).
    #[default]
    Spill,
    /// Drop the victim's pages and replay prompt + already-sampled
    /// tokens through prefill on resume (compute for host memory).
    Recompute,
}

impl PreemptMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            PreemptMode::Off => "off",
            PreemptMode::Spill => "spill",
            PreemptMode::Recompute => "recompute",
        }
    }

    pub fn parse(s: &str) -> Result<PreemptMode> {
        match s {
            "off" => Ok(PreemptMode::Off),
            "spill" => Ok(PreemptMode::Spill),
            "recompute" => Ok(PreemptMode::Recompute),
            other => bail!("unknown preempt mode {other:?} (expected off|spill|recompute)"),
        }
    }
}

/// Element encoding for KV pool pages (see `kvcache::codec`). The pool
/// stores *coded* bytes: f32 is the passthrough layout, f16 halves pool
/// bytes with bit-exact round-trip determinism, int8 quarters them with
/// per-row round-to-nearest scales (epsilon-level attention error,
/// pinned by the paged-KV property tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KvDtype {
    /// 4-byte passthrough — tile reads borrow pool memory directly.
    #[default]
    F32,
    /// IEEE half precision (round-to-nearest-even). Decode is exact for
    /// the stored value, so paged runs are deterministic bit-for-bit.
    F16,
    /// 1-byte RTN quantization with one f32 scale per kv_dim row
    /// (per page, per layer, per K/V, per position).
    Int8,
}

impl KvDtype {
    /// Coded bytes per element (excluding the int8 scale sidecar, which
    /// `kvcache::KvLayout` accounts separately).
    pub fn elem_bytes(&self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::F16 => 2,
            KvDtype::Int8 => 1,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Result<KvDtype> {
        match s {
            "f32" => Ok(KvDtype::F32),
            "f16" => Ok(KvDtype::F16),
            "int8" => Ok(KvDtype::Int8),
            other => bail!("unknown kv dtype {other:?} (expected f32|f16|int8)"),
        }
    }
}

/// Paged KV-cache settings for the native backend (`kv` section): the
/// page granularity of `kvcache::BlockPool`, the pool's total size, and
/// the multi-tenant policies (prefix sharing, preemption).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvConfig {
    /// Tokens per pool page — also the chunked attention kernel's tile
    /// height. Smaller pages waste less tail memory per sequence but make
    /// the page table (and the attention tile loop) proportionally longer.
    pub page_size: usize,
    /// Total pool pages shared by every slot. `0` (the default) sizes the
    /// pool to `slots × ceil(max_seq / page_size)` — the same capacity the
    /// per-slot contiguous caches would hold, so default configs change
    /// layout, not memory bounds. Set it lower to oversubscribe: the
    /// batcher then admits on free pages instead of free slots.
    pub pool_pages: usize,
    /// Share full prompt-prefix pages across requests (hash-identified,
    /// copy-on-write; `kvcache::prefix`). On by default — sharing is
    /// bit-exact, so the only cost is the index bookkeeping.
    pub prefix_cache: bool,
    /// Preemption policy when the pool saturates (see [`PreemptMode`]).
    pub preempt: PreemptMode,
    /// Page element encoding (see [`KvDtype`]). `CODEGEMM_KV_DTYPE`
    /// overrides it at pool construction, mirroring `CODEGEMM_KERNEL`.
    pub kv_dtype: KvDtype,
}

impl Default for KvConfig {
    fn default() -> Self {
        KvConfig {
            page_size: 16,
            pool_pages: 0,
            prefix_cache: true,
            preempt: PreemptMode::default(),
            kv_dtype: KvDtype::default(),
        }
    }
}

impl KvConfig {
    /// Largest accepted page size (tokens). A page is the pool's
    /// allocation quantum — `n_layers × 2 × page_size × kv_dim` floats —
    /// so a fat-fingered `--page-size 100000000` would try to allocate
    /// gigabyte pages; reject it at parse time instead of OOMing.
    pub const MAX_PAGE_SIZE: usize = 1 << 20;

    /// Validate at config parse: every construction path (JSON sections,
    /// the `serve --page-size/--pool-pages` flags, direct construction
    /// via [`crate::kvcache::BlockPool::for_model`]) runs this, so a
    /// zero or absurd page size fails with a clean error instead of a
    /// divide-by-zero or an unusable pool deeper in the stack.
    /// (`pool_pages == 0` is valid: it means auto-size, see
    /// [`KvConfig::pool_pages_for`].)
    pub fn validate(&self) -> Result<()> {
        if self.page_size == 0 {
            bail!("kv page_size must be positive (tokens per pool page)");
        }
        if self.page_size > Self::MAX_PAGE_SIZE {
            bail!(
                "kv page_size {} exceeds the maximum {} (one page is the pool's \
                 allocation quantum)",
                self.page_size,
                Self::MAX_PAGE_SIZE
            );
        }
        Ok(())
    }

    /// Resolved pool size for `slots` serving slots of `max_seq` context.
    pub fn pool_pages_for(&self, max_seq: usize, slots: usize) -> usize {
        if self.pool_pages > 0 {
            self.pool_pages
        } else {
            // `max(1)` guards unvalidated direct construction — validated
            // configs always have page_size >= 1.
            slots.max(1) * max_seq.div_ceil(self.page_size.max(1))
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("page_size", Json::from(self.page_size)),
            ("pool_pages", Json::from(self.pool_pages)),
            ("prefix_cache", Json::Bool(self.prefix_cache)),
            ("preempt", Json::Str(self.preempt.as_str().to_string())),
            ("kv_dtype", Json::Str(self.kv_dtype.as_str().to_string())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<KvConfig> {
        let d = KvConfig::default();
        let cfg = KvConfig {
            page_size: j.opt_usize("page_size", d.page_size)?,
            pool_pages: j.opt_usize("pool_pages", d.pool_pages)?,
            // Optional fields: absent ⇒ defaults (older configs parse
            // unchanged).
            prefix_cache: j
                .get("prefix_cache")
                .and_then(|v| v.as_bool())
                .unwrap_or(d.prefix_cache),
            preempt: match j.get("preempt").and_then(|v| v.as_str()) {
                Some(s) => PreemptMode::parse(s)?,
                None => d.preempt,
            },
            kv_dtype: match j.get("kv_dtype").and_then(|v| v.as_str()) {
                Some(s) => KvDtype::parse(s)?,
                None => d.kv_dtype,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Options for the request coordinator (router + batcher + scheduler).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Maximum decode batch size. The AOT artifacts are compiled per batch
    /// size; the batcher only forms batches whose size has an artifact.
    pub max_batch: usize,
    /// Batch-formation window: how long the batcher waits for more
    /// requests before dispatching a partial batch (microseconds).
    pub batch_window_us: u64,
    /// Maximum new tokens per request (hard cap).
    pub max_new_tokens: usize,
    /// Sampling temperature (0 ⇒ greedy).
    pub temperature: f32,
    /// Queue capacity before admission control rejects requests.
    pub queue_capacity: usize,
    /// Worker threads executing model steps.
    pub workers: usize,
    /// **Shared** per-step prefill token budget across all prefilling
    /// slots (not per slot), so decode stall per step is bounded no matter
    /// how many prompts are in flight. Prompts longer than the budget
    /// resume on subsequent steps (round-robin across slots).
    pub prefill_budget: usize,
    /// Sharded-execution settings for the native backend (`parallel`
    /// section; serial by default so existing configs are unchanged).
    pub parallel: ParallelConfig,
    /// Paged KV-pool settings for the native backend (`kv` section).
    pub kv: KvConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            batch_window_us: 2_000,
            max_new_tokens: 64,
            temperature: 0.0,
            queue_capacity: 256,
            workers: 1,
            prefill_budget: 128,
            parallel: ParallelConfig::serial(),
            kv: KvConfig::default(),
        }
    }
}

impl ServeConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_batch", Json::from(self.max_batch)),
            ("batch_window_us", Json::from(self.batch_window_us as usize)),
            ("max_new_tokens", Json::from(self.max_new_tokens)),
            ("temperature", Json::Num(self.temperature as f64)),
            ("queue_capacity", Json::from(self.queue_capacity)),
            ("workers", Json::from(self.workers)),
            ("prefill_budget", Json::from(self.prefill_budget)),
            ("parallel", self.parallel.to_json()),
            ("kv", self.kv.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        Ok(ServeConfig {
            max_batch: j.req_usize("max_batch")?,
            batch_window_us: j.req_usize("batch_window_us")? as u64,
            max_new_tokens: j.req_usize("max_new_tokens")?,
            temperature: j.req_f64("temperature")? as f32,
            queue_capacity: j.req_usize("queue_capacity")?,
            workers: j.req_usize("workers")?,
            // Optional field: absent ⇒ default (older configs unchanged).
            prefill_budget: j.opt_usize("prefill_budget", d.prefill_budget)?,
            // Optional section: absent ⇒ serial (older configs unchanged).
            parallel: match j.get("parallel") {
                Some(p) => ParallelConfig::from_json(p)?,
                None => ParallelConfig::serial(),
            },
            // Optional section: absent ⇒ default paging (older configs
            // unchanged — the auto pool matches contiguous capacity).
            kv: match j.get("kv") {
                Some(k) => KvConfig::from_json(k)?,
                None => KvConfig::default(),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_sane() {
        let c = ServeConfig::default();
        assert!(c.max_batch >= 1);
        assert!(c.queue_capacity >= c.max_batch);
    }

    #[test]
    fn json_roundtrip() {
        let c = ServeConfig { max_batch: 4, temperature: 0.7, ..Default::default() };
        let j = Json::parse(&c.to_json().to_string_pretty()).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap(), c);
    }

    #[test]
    fn json_roundtrip_with_parallel_section() {
        let c = ServeConfig {
            parallel: ParallelConfig { num_threads: 4, shard_min_rows: 128, ..Default::default() },
            ..Default::default()
        };
        let j = Json::parse(&c.to_json().to_string_pretty()).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap(), c);
    }

    #[test]
    fn missing_parallel_section_defaults_to_serial() {
        let c = ServeConfig::default();
        let mut j = c.to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("parallel");
        }
        let parsed = ServeConfig::from_json(&j).unwrap();
        assert!(parsed.parallel.is_serial());
    }

    #[test]
    fn kv_config_roundtrip_and_validation() {
        let kv = KvConfig {
            page_size: 32,
            pool_pages: 100,
            prefix_cache: false,
            preempt: PreemptMode::Recompute,
            kv_dtype: KvDtype::F16,
        };
        kv.validate().unwrap();
        let j = Json::parse(&kv.to_json().to_string_pretty()).unwrap();
        assert_eq!(KvConfig::from_json(&j).unwrap(), kv);
        // Missing fields fall back to defaults — configs written before
        // prefix caching / preemption existed parse unchanged.
        let j = Json::parse(r#"{"page_size": 8}"#).unwrap();
        let c = KvConfig::from_json(&j).unwrap();
        assert_eq!(c.page_size, 8);
        assert_eq!(c.pool_pages, 0);
        assert!(c.prefix_cache);
        assert_eq!(c.preempt, PreemptMode::Spill);
        assert_eq!(c.kv_dtype, KvDtype::F32);
        // page_size 0 is rejected.
        let bad = Json::parse(r#"{"page_size": 0}"#).unwrap();
        assert!(KvConfig::from_json(&bad).is_err());
        // Unknown preempt modes are rejected, not silently defaulted.
        let bad = Json::parse(r#"{"preempt": "yolo"}"#).unwrap();
        assert!(KvConfig::from_json(&bad).is_err());
    }

    #[test]
    fn kv_dtype_roundtrip_and_rejection() {
        for (s, d) in [("f32", KvDtype::F32), ("f16", KvDtype::F16), ("int8", KvDtype::Int8)] {
            assert_eq!(KvDtype::parse(s).unwrap(), d);
            assert_eq!(d.as_str(), s);
        }
        assert_eq!(KvDtype::F32.elem_bytes(), 4);
        assert_eq!(KvDtype::F16.elem_bytes(), 2);
        assert_eq!(KvDtype::Int8.elem_bytes(), 1);
        let kv = KvConfig { kv_dtype: KvDtype::Int8, ..KvConfig::default() };
        let j = Json::parse(&kv.to_json().to_string_pretty()).unwrap();
        assert_eq!(KvConfig::from_json(&j).unwrap(), kv);
        // Unknown dtypes are rejected, not silently defaulted.
        let bad = Json::parse(r#"{"kv_dtype": "int4"}"#).unwrap();
        assert!(KvConfig::from_json(&bad).is_err());
    }

    /// The serve CLI builds a `KvConfig` straight from `--page-size` /
    /// `--pool-pages` and validates it; both degenerate page sizes must
    /// fail with a clean error, and a zero-page-size config must never
    /// reach the pool math (divide-by-zero) even unvalidated.
    #[test]
    fn kv_rejects_degenerate_page_sizes_cleanly() {
        let zero = KvConfig { page_size: 0, ..KvConfig::default() };
        let err = zero.validate().unwrap_err().to_string();
        assert!(err.contains("page_size"), "unhelpful error: {err}");
        // Unvalidated direct use must not divide by zero.
        assert!(zero.pool_pages_for(128, 4) >= 1);

        let huge = KvConfig { page_size: KvConfig::MAX_PAGE_SIZE + 1, ..KvConfig::default() };
        assert!(huge.validate().is_err());
        let max = KvConfig { page_size: KvConfig::MAX_PAGE_SIZE, ..KvConfig::default() };
        max.validate().unwrap();
        // pool_pages = 0 is the documented auto-sizing value, not an error.
        KvConfig { page_size: 16, pool_pages: 0, ..KvConfig::default() }.validate().unwrap();
    }

    /// A bad `kv` section must fail the whole `ServeConfig` parse (the
    /// JSON path the server loads), not limp into an unusable pool.
    #[test]
    fn serve_config_rejects_bad_kv_section() {
        let mut j = ServeConfig::default().to_json();
        if let Json::Obj(map) = &mut j {
            map.insert(
                "kv".into(),
                Json::parse(r#"{"page_size": 0, "pool_pages": 4}"#).unwrap(),
            );
        }
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn kv_pool_auto_sizing() {
        let kv = KvConfig { page_size: 16, pool_pages: 0, ..KvConfig::default() };
        // 4 slots × ceil(130/16) = 4 × 9.
        assert_eq!(kv.pool_pages_for(130, 4), 36);
        // Explicit pool size wins.
        let kv = KvConfig { page_size: 16, pool_pages: 7, ..KvConfig::default() };
        assert_eq!(kv.pool_pages_for(130, 4), 7);
    }

    #[test]
    fn missing_kv_and_budget_fields_default() {
        let c = ServeConfig::default();
        let mut j = c.to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("kv");
            map.remove("prefill_budget");
        }
        let parsed = ServeConfig::from_json(&j).unwrap();
        assert_eq!(parsed.kv, KvConfig::default());
        assert_eq!(parsed.prefill_budget, ServeConfig::default().prefill_budget);
    }
}
