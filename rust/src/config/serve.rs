//! Serving configuration for the L3 coordinator.

use super::ParallelConfig;
use crate::util::json::Json;
use anyhow::Result;

/// Options for the request coordinator (router + batcher + scheduler).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Maximum decode batch size. The AOT artifacts are compiled per batch
    /// size; the batcher only forms batches whose size has an artifact.
    pub max_batch: usize,
    /// Batch-formation window: how long the batcher waits for more
    /// requests before dispatching a partial batch (microseconds).
    pub batch_window_us: u64,
    /// Maximum new tokens per request (hard cap).
    pub max_new_tokens: usize,
    /// Sampling temperature (0 ⇒ greedy).
    pub temperature: f32,
    /// Queue capacity before admission control rejects requests.
    pub queue_capacity: usize,
    /// Worker threads executing model steps.
    pub workers: usize,
    /// Sharded-execution settings for the native backend (`parallel`
    /// section; serial by default so existing configs are unchanged).
    pub parallel: ParallelConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            batch_window_us: 2_000,
            max_new_tokens: 64,
            temperature: 0.0,
            queue_capacity: 256,
            workers: 1,
            parallel: ParallelConfig::serial(),
        }
    }
}

impl ServeConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_batch", Json::from(self.max_batch)),
            ("batch_window_us", Json::from(self.batch_window_us as usize)),
            ("max_new_tokens", Json::from(self.max_new_tokens)),
            ("temperature", Json::Num(self.temperature as f64)),
            ("queue_capacity", Json::from(self.queue_capacity)),
            ("workers", Json::from(self.workers)),
            ("parallel", self.parallel.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ServeConfig> {
        Ok(ServeConfig {
            max_batch: j.req_usize("max_batch")?,
            batch_window_us: j.req_usize("batch_window_us")? as u64,
            max_new_tokens: j.req_usize("max_new_tokens")?,
            temperature: j.req_f64("temperature")? as f32,
            queue_capacity: j.req_usize("queue_capacity")?,
            workers: j.req_usize("workers")?,
            // Optional section: absent ⇒ serial (older configs unchanged).
            parallel: match j.get("parallel") {
                Some(p) => ParallelConfig::from_json(p)?,
                None => ParallelConfig::serial(),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_sane() {
        let c = ServeConfig::default();
        assert!(c.max_batch >= 1);
        assert!(c.queue_capacity >= c.max_batch);
    }

    #[test]
    fn json_roundtrip() {
        let c = ServeConfig { max_batch: 4, temperature: 0.7, ..Default::default() };
        let j = Json::parse(&c.to_json().to_string_pretty()).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap(), c);
    }

    #[test]
    fn json_roundtrip_with_parallel_section() {
        let c = ServeConfig {
            parallel: ParallelConfig { num_threads: 4, shard_min_rows: 128, ..Default::default() },
            ..Default::default()
        };
        let j = Json::parse(&c.to_json().to_string_pretty()).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap(), c);
    }

    #[test]
    fn missing_parallel_section_defaults_to_serial() {
        let c = ServeConfig::default();
        let mut j = c.to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("parallel");
        }
        let parsed = ServeConfig::from_json(&j).unwrap();
        assert!(parsed.parallel.is_serial());
    }
}
