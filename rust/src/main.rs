//! `codegemm` — leader entrypoint + CLI.
//!
//! Subcommands:
//! - `tables`      regenerate the paper's tables/figures (model vs paper)
//! - `serve`       run the serving coordinator on the AOT artifacts (or the
//!                 native backend) against a synthetic request workload
//! - `bench-serve` trace-driven scenario harness: seeded workload mix →
//!                 serving coordinator → versioned `BENCH_<n>.json`
//!                 artifact, with an optional regression diff vs a
//!                 previous artifact
//! - `quantize`    quantize a layer and report footprint / error / engine
//!                 agreement
//! - `bench`       quick CPU-engine micro-benchmarks (full suite: cargo bench)
//! - `profile`     calibrate machine peaks (STREAM bandwidth, peak MACs) and
//!                 place the kernel's exact byte/MAC counters under the
//!                 roofline, phase by phase, plus a cache-footprint audit
//! - `doctor`      environment self-checks (PJRT client, artifacts)

use codegemm::bench::harness::{run_bench, BenchOptions};
use codegemm::bench::tables::{self, EvalContext};
use codegemm::config::{KernelConfig, KernelImpl, ModelConfig, ParallelConfig, QuantConfig, ServeConfig};
use codegemm::coordinator::{DecodeBackend, NativeBackend, PjrtBackend, Request, Server};
use codegemm::coordinator::MetricsReport;
use codegemm::gemm::{CodeGemmEngine, Counters, DenseEngine, DequantEngine, GemmEngine, Psumbook};
use codegemm::model::{EngineKind, ModelWeights};
use codegemm::obs::prof::{self, ProfSummary};
use codegemm::obs::roofline::{analyze, calibrate, CacheSizes, FootprintAudit};
use codegemm::obs::{check_slo, compare, drive, generate, BenchArtifact, WorkloadMix};
use codegemm::quant::footprint::bits_per_weight;
use codegemm::quant::Quantizer;
use codegemm::runtime::{pjrt_self_test, ModelRuntime};
use codegemm::util::argparse::Command;
use codegemm::util::prng::Prng;
use codegemm::util::stats;
use codegemm::util::table::fnum;
use std::path::Path;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    format!(
        "codegemm {} — codebook-centric GEMM stack (CodeGEMM reproduction)\n\n\
         USAGE: codegemm <subcommand> [options]\n\n\
         SUBCOMMANDS:\n  \
           tables    --table <1..10|fig4a|fig4b|fig5|all> [--artifacts DIR]\n  \
           serve     [--artifacts DIR] [--backend pjrt|native] [--requests N] [--batch N] [--threads N]\n              \
                     [--kernel-impl auto|scalar|unrolled|avx2] [--simd-lanes 0|1|8|16] [--pipeline-tiles on|off]\n              \
                     [--prefix-cache on|off] [--preempt off|spill|recompute] [--kv-dtype f32|f16|int8]\n  \
           bench-serve [--workload chat|rag|longform|bursty|mixed] [--seed N] [--requests N]\n              \
                     [--out BENCH_6.json] [--baseline PREV.json] [--threshold 0.2] [--advisory]\n              \
                     [--repeats N] [--profile on|off] [--trace-out trace.json]\n  \
           quantize  --config m1v4g128 [--n 512] [--k 512]\n  \
           bench     [--n 1024] [--k 1024]\n  \
           profile   [--config m1v4g128] [--n 1024] [--k 1024] [--batch 1] [--quick]\n              \
                     [--kernel-impl auto|scalar|unrolled|avx2] [--simd-lanes 0|1|8|16]\n  \
           doctor    [--artifacts DIR]\n",
        codegemm::VERSION
    )
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let Some(sub) = args.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "tables" => cmd_tables(rest),
        "serve" => cmd_serve(rest),
        "bench-serve" => cmd_bench_serve(rest),
        "quantize" => cmd_quantize(rest),
        "bench" => cmd_bench(rest),
        "profile" => cmd_profile(rest),
        "doctor" => cmd_doctor(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}'\n{}", usage()),
    }
}

// ----------------------------------------------------------------- tables

fn cmd_tables(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("tables", "regenerate the paper's tables and figures")
        .opt("table", Some("all"), "table id (1..10, fig4a, fig4b, fig5) or 'all'")
        .flag("all", "regenerate everything")
        .opt("artifacts", Some("artifacts"), "artifacts dir for the accuracy substrate");
    let m = cmd.parse(args)?;
    let ctx = EvalContext::load(Path::new(m.str("artifacts")?));
    let want = if m.flag("all") { "all" } else { m.str("table")? };
    let ids: Vec<&str> = if want == "all" {
        tables::all_ids().to_vec()
    } else {
        vec![want]
    };
    for id in ids {
        match tables::render(id, &ctx) {
            Some(text) => println!("{text}"),
            None => anyhow::bail!("unknown table id '{id}' (valid: {:?})", tables::all_ids()),
        }
    }
    Ok(())
}

// ------------------------------------------------------------------ serve

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("serve", "serve a synthetic workload through the coordinator")
        .opt("artifacts", Some("artifacts"), "AOT artifacts dir")
        .opt("backend", Some("auto"), "pjrt | native | auto")
        .opt("requests", Some("32"), "number of requests")
        .opt("batch", Some("4"), "max batch (native backend)")
        .opt("max-new", Some("24"), "max new tokens per request")
        .opt("prompt-len", Some("16"), "prompt length (tokens)")
        .opt("threads", Some("1"), "shard the native model across N worker threads (0 = auto)")
        .opt("page-size", Some("16"), "KV pool page size in tokens (native backend)")
        .opt("pool-pages", Some("0"), "KV pool pages shared by all slots (0 = auto)")
        .opt(
            "prefix-cache",
            Some("on"),
            "share identical prompt prefixes via refcounted pool pages (on|off)",
        )
        .opt(
            "preempt",
            Some("spill"),
            "swap lower-priority decodes out for admission: off | spill | recompute",
        )
        .opt(
            "kv-dtype",
            Some("f32"),
            "KV page codec: f32 | f16 | int8 (CODEGEMM_KV_DTYPE overrides)",
        )
        .opt(
            "fused-projections",
            Some("on"),
            "fuse Q/K/V and gate/up around one Psumbook build per k-tile (on|off)",
        )
        .opt(
            "kernel-impl",
            Some("auto"),
            "CodeGEMM kernel: auto (AVX2 when the CPU has it) | scalar | unrolled | avx2",
        )
        .opt(
            "simd-lanes",
            Some("0"),
            "gather/build lane width: 0 = auto, 1 = scalar, 8 or 16 unrolled lanes",
        )
        .opt(
            "pipeline-tiles",
            Some("on"),
            "overlap the next k-tile's Psumbook build with the current tile's gather (on|off)",
        );
    let m = cmd.parse(args)?;
    let artifacts = Path::new(m.str("artifacts")?);
    let n_requests = m.usize("requests")?;
    let max_new = m.usize("max-new")?;
    let prompt_len = m.usize("prompt-len")?;
    let want = m.str("backend")?;
    let fused_projections = match m.str("fused-projections")? {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => anyhow::bail!("--fused-projections expects on|off, got '{other}'"),
    };
    let impl_arg = m.str("kernel-impl")?;
    let kernel_impl = KernelImpl::parse(impl_arg).ok_or_else(|| {
        anyhow::anyhow!("--kernel-impl expects auto|scalar|unrolled|avx2, got '{impl_arg}'")
    })?;
    let pipeline_tiles = match m.str("pipeline-tiles")? {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => anyhow::bail!("--pipeline-tiles expects on|off, got '{other}'"),
    };
    let kernel = KernelConfig {
        kernel_impl,
        simd_lanes: m.usize("simd-lanes")?,
        pipeline_tiles,
        ..KernelConfig::default()
    };
    let parallel = ParallelConfig {
        num_threads: m.usize("threads")?,
        fused_projections,
        ..Default::default()
    };

    let prefix_cache = match m.str("prefix-cache")? {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => anyhow::bail!("--prefix-cache expects on|off, got '{other}'"),
    };
    let kv = codegemm::config::KvConfig {
        page_size: m.usize("page-size")?,
        pool_pages: m.usize("pool-pages")?,
        prefix_cache,
        preempt: codegemm::config::PreemptMode::parse(m.str("preempt")?)?,
        kv_dtype: codegemm::config::KvDtype::parse(m.str("kv-dtype")?)?,
    };
    kv.validate()?;
    let cfg = ServeConfig {
        max_batch: m.usize("batch")?,
        max_new_tokens: max_new,
        parallel,
        kv,
        ..Default::default()
    };
    let (backend, label): (Box<dyn DecodeBackend>, String) =
        if want != "native" && artifacts.join("manifest.json").exists() {
            let rt = ModelRuntime::load(artifacts)?;
            let be = PjrtBackend::new(rt);
            let label = be.label();
            (Box::new(be), label)
        } else {
            if want == "pjrt" {
                anyhow::bail!("--backend pjrt requested but no artifacts at {}", artifacts.display());
            }
            let weights = load_or_random_weights(artifacts);
            let kind = EngineKind::codegemm_with_kernel(QuantConfig::new(4, 1, 8, 32)?, kernel);
            if let Some(sel) = kind.kernel_sel() {
                println!("kernel:  {} ({} lanes)", sel.label(), sel.lanes);
            }
            // Both branches honor the fused-projections toggle; the
            // worker pool is only spawned when the config actually
            // shards.
            let be = if cfg.parallel.is_serial() {
                NativeBackend::with_kv_fused(
                    &weights,
                    kind,
                    cfg.max_batch,
                    &cfg.kv,
                    cfg.parallel.fused_projections_effective(),
                )
            } else {
                let pool = std::sync::Arc::new(
                    codegemm::util::threadpool::ThreadPool::with_threads(
                        cfg.parallel.effective_threads(),
                    ),
                );
                NativeBackend::new_parallel_kv(
                    &weights,
                    kind,
                    cfg.max_batch,
                    &cfg.parallel,
                    pool,
                    &cfg.kv,
                )
            };
            let label = be.label();
            (Box::new(be), label)
        };
    println!("backend: {label}");
    let server = Server::start(backend, cfg);

    // Synthetic workload: corpus-like byte prompts.
    let mut rng = Prng::seeded(42);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            let prompt: Vec<usize> = (0..prompt_len).map(|_| rng.index(255) + 1).collect();
            server.submit(Request::new(i as u64, prompt, max_new))
        })
        .collect();
    let mut total_tokens = 0usize;
    for h in handles {
        total_tokens += h.wait().tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = server.shutdown();
    println!("{}", report.render());
    println!(
        "wall: {:.2}s — {:.1} generated tok/s end-to-end ({} tokens / {} requests)",
        wall,
        total_tokens as f64 / wall,
        total_tokens,
        n_requests
    );
    Ok(())
}

// ------------------------------------------------------------ bench-serve

fn cmd_bench_serve(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "bench-serve",
        "seeded serving scenario → versioned BENCH artifact (+ regression diff)",
    )
    .opt("workload", Some("chat"), "chat | rag | longform | bursty | mixed")
    .opt("seed", Some("7"), "workload seed (same seed ⇒ same request trace)")
    .opt("requests", Some("0"), "request count (0 = 48, or 12 under CODEGEMM_BENCH_QUICK=1)")
    .opt("batch", Some("4"), "max batch")
    .opt("out", Some("BENCH_6.json"), "artifact output path")
    .opt("baseline", None, "previous BENCH artifact to diff against")
    .opt("threshold", Some("0.2"), "relative regression threshold for the comparator")
    .flag("advisory", "report comparator findings without failing (exit 0)")
    .opt("artifacts", Some("artifacts"), "weights dir (weights.f32.bin used when present)")
    .opt("repeats", Some("1"), "run the scenario N times; report per-gauge min/max/stddev spread")
    .opt("profile", Some("off"), "kernel profiler on|off: per-worker timelines → overlap/occupancy gauges")
    .opt("trace-out", None, "write the traced run's Chrome trace-event JSON here (implies --profile on)");
    let m = cmd.parse(args)?;

    let workload = m.str("workload")?;
    let Some(mix) = WorkloadMix::by_name(workload) else {
        anyhow::bail!("unknown workload '{workload}' (valid: {:?})", WorkloadMix::names());
    };
    let seed = m.usize("seed")? as u64;
    let quick = std::env::var("CODEGEMM_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let n_requests = match m.usize("requests")? {
        0 if quick => 12,
        0 => 48,
        n => n,
    };

    let repeats = m.usize("repeats")?.max(1);
    let trace_out = m.get("trace-out").map(std::path::PathBuf::from);
    let profile_on = match m.str("profile")? {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => trace_out.is_some(),
        other => anyhow::bail!("--profile expects on|off, got '{other}'"),
    };

    let model_cfg = ModelConfig::tiny();
    let weights = load_or_random_weights(Path::new(m.str("artifacts")?));
    let kind = EngineKind::codegemm(QuantConfig::new(4, 1, 8, 32)?);
    let cfg = ServeConfig { max_batch: m.usize("batch")?, temperature: 0.0, ..Default::default() };

    let trace = generate(&mix, seed, n_requests, model_cfg.vocab);
    let mut label = String::new();
    let mut reports: Vec<MetricsReport> = Vec::new();
    for rep in 0..repeats {
        let backend = NativeBackend::with_kv_fused(
            &weights,
            kind,
            cfg.max_batch,
            &cfg.kv,
            cfg.parallel.fused_projections_effective(),
        );
        if rep == 0 {
            label = backend.label();
            println!(
                "backend: {label}  workload: {} ({n_requests} requests, seed {seed})",
                mix.name
            );
        }
        // Only the first repeat is traced: the artifact's gauges come
        // from it, and later repeats measure undisturbed speed for the
        // spread rows.
        let traced = profile_on && rep == 0;
        if traced {
            let _ = prof::drain(); // discard anything a previous run left behind
            prof::enable();
        }
        let server = Server::start(Box::new(backend), cfg.clone());
        let t0 = std::time::Instant::now();
        let responses = drive(&server, &trace);
        let wall = t0.elapsed().as_secs_f64();
        if traced {
            prof::disable();
            let tl = prof::drain();
            let mut summary = ProfSummary::from_timeline(&tl);
            // Quick bandwidth calibration so the report can show gather
            // GB/s achieved against an attainable peak.
            summary.gather_gbs_peak = calibrate(&CacheSizes::detect(), true).bw_gbs;
            if let Some(path) = &trace_out {
                std::fs::write(path, tl.to_chrome_trace().to_string_pretty())?;
                println!(
                    "trace: {} ({} events across {} threads, {} dropped)",
                    path.display(),
                    tl.events.len(),
                    tl.threads.len(),
                    tl.dropped
                );
            }
            server.record_prof(summary);
        }
        let report = server.shutdown();
        if rep == 0 {
            println!("{}", report.render());
            let generated: usize = responses.iter().map(|r| r.tokens.len()).sum();
            println!("wall: {wall:.2}s — {generated} tokens generated");
        }
        reports.push(report);
    }
    let report = &reports[0];

    let mut spread: Vec<(String, f64, f64, f64)> = Vec::new();
    if repeats > 1 {
        let gauges: [(&str, fn(&MetricsReport) -> f64); 4] = [
            ("decode_tok_s", |r| r.tokens_per_s),
            ("ttft_p99_s", |r| r.ttft.p99),
            ("tpot_p99_s", |r| r.tpot.p99),
            ("latency_p99_s", |r| r.latency.p99),
        ];
        for (name, get) in gauges {
            let vals: Vec<f64> = reports.iter().map(get).collect();
            let (lo, hi, sd) = spread_of(&vals);
            println!(
                "spread: {name} over {repeats} runs — min {lo:.4}, max {hi:.4}, stddev {sd:.4}"
            );
            spread.push((name.to_string(), lo, hi, sd));
        }
    }

    let violations = check_slo(&mix.slo, report);
    if violations.is_empty() {
        println!(
            "slo: all met (ttft p99 ≤ {:.0} ms, tpot p95 ≤ {:.0} ms, decode ≥ {:.0} tok/s)",
            mix.slo.ttft_p99_s * 1e3,
            mix.slo.tpot_p95_s * 1e3,
            mix.slo.min_decode_tok_s,
        );
    } else {
        for v in &violations {
            println!("slo: VIOLATION — {v}");
        }
    }

    let out = std::path::PathBuf::from(m.str("out")?);
    let bench_id = out.file_stem().and_then(|s| s.to_str()).unwrap_or("BENCH").to_string();
    let mut artifact =
        BenchArtifact::from_report(&bench_id, mix.name, seed, n_requests, &label, report, violations);
    artifact.repeats = repeats;
    artifact.spread = spread;
    artifact.save(&out)?;
    println!("artifact: {} (schema v{})", out.display(), artifact.schema_version);

    if let Some(base_path) = m.get("baseline") {
        let threshold = m.f64("threshold")?;
        let baseline = BenchArtifact::load(Path::new(base_path))?;
        let findings = compare(&baseline, &artifact, threshold);
        if findings.is_empty() {
            println!(
                "comparator: no regressions vs {base_path} (threshold {:.0}%)",
                100.0 * threshold
            );
        } else {
            for f in &findings {
                println!("comparator: {f}");
            }
            if !m.flag("advisory") {
                anyhow::bail!("{} regression(s) vs baseline {base_path}", findings.len());
            }
            println!("comparator: advisory mode — not failing the run");
        }
    }
    Ok(())
}

/// (min, max, population stddev) of a gauge sample.
fn spread_of(vals: &[f64]) -> (f64, f64, f64) {
    let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mean = vals.iter().sum::<f64>() / vals.len().max(1) as f64;
    let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len().max(1) as f64;
    (lo, hi, var.sqrt())
}

fn load_or_random_weights(artifacts: &Path) -> ModelWeights {
    let wf = artifacts.join("weights.f32.bin");
    if wf.exists() {
        if let Ok(w) = ModelWeights::load(ModelConfig::tiny(), &wf) {
            return w;
        }
    }
    ModelWeights::random(ModelConfig::tiny(), 7)
}

// --------------------------------------------------------------- quantize

fn cmd_quantize(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("quantize", "quantize a random layer; report error + engine agreement")
        .opt("config", Some("m1v4g128"), "quant config label (e.g. m2v8g128)")
        .opt("n", Some("512"), "rows")
        .opt("k", Some("512"), "cols")
        .opt("refine", Some("1"), "alternating refinement rounds");
    let m = cmd.parse(args)?;
    let cfg = QuantConfig::parse_label(m.str("config")?)?;
    let (n, k) = (m.usize("n")?, m.usize("k")?);
    let w = Prng::seeded(1).normal_vec(n * k, 0.02);
    let t0 = std::time::Instant::now();
    let q = Quantizer::new(cfg).with_refinement(m.usize("refine")?).quantize(&w, n, k);
    let dt = t0.elapsed().as_secs_f64();
    let wq = q.dequantize();
    let f = bits_per_weight(&cfg, n, k);
    println!("config {} on {n}×{k}  ({dt:.2}s)", cfg.label());
    println!(
        "  q̄ = {} bits (codes {}, codebooks {}, scales {})",
        fnum(f.total, 3),
        fnum(f.q_code, 3),
        fnum(f.q_codebook, 3),
        fnum(f.q_norm, 3)
    );
    println!(
        "  storage: {} bytes ({}× smaller than fp16)",
        q.storage_bytes(),
        fnum(2.0 * (n * k) as f64 / q.storage_bytes() as f64, 2)
    );
    println!("  reconstruction rel-err: {}", fnum(stats::rel_l2(&wq, &w), 4));
    // engine agreement
    let x = Prng::seeded(2).normal_vec(k, 1.0);
    let mut cg = CodeGemmEngine::from_quantized(&q);
    let mut dq = DequantEngine::from_quantized(&q);
    let mut dense = DenseEngine::new(wq, n, k);
    let (y_cg, y_dq, y_ref) = (cg.gemv(&x), dq.gemv(&x), dense.gemv(&x));
    println!("  CodeGEMM vs dequantized-dense rel: {:.2e}", stats::rel_l2(&y_cg, &y_ref));
    println!("  Dequant  vs dequantized-dense rel: {:.2e}", stats::rel_l2(&y_dq, &y_ref));
    println!("  Psumbook bytes/tile: {} (codebook would be {})", cg.psumbook_bytes(), dq.codebook_bytes());
    Ok(())
}

// ------------------------------------------------------------------ bench

fn cmd_bench(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("bench", "quick CPU-engine micro-bench")
        .opt("n", Some("1024"), "rows")
        .opt("k", Some("1024"), "cols")
        .opt("batch", Some("1"), "batch columns");
    let m = cmd.parse(args)?;
    let (n, k, mb) = (m.usize("n")?, m.usize("k")?, m.usize("batch")?);
    let w = Prng::seeded(1).normal_vec(n * k, 0.02);
    let x = Prng::seeded(2).normal_vec(k * mb, 1.0);
    let opts = BenchOptions::from_env();
    println!("CPU engines on {n}×{k}, batch {mb} (not A100 numbers — see `tables` for the model):");
    let mut dense = DenseEngine::new(w.clone(), n, k);
    println!(
        "  {}",
        run_bench("fp32-dense", opts, || {
            codegemm::bench::harness::black_box(dense.gemm(&x, mb));
        })
        .line()
    );
    for label in ["m1v4g128", "m2v8g128"] {
        let cfg = QuantConfig::parse_label(label)?;
        let q = Quantizer::new(cfg).quantize(&w, n, k);
        let mut cg = CodeGemmEngine::from_quantized(&q);
        let mut dq = DequantEngine::from_quantized(&q);
        println!(
            "  {}",
            run_bench(&format!("codegemm-{label}"), opts, || {
                codegemm::bench::harness::black_box(cg.gemm(&x, mb));
            })
            .line()
        );
        println!(
            "  {}",
            run_bench(&format!("dequant-{label}"), opts, || {
                codegemm::bench::harness::black_box(dq.gemm(&x, mb));
            })
            .line()
        );
    }
    Ok(())
}

// ---------------------------------------------------------------- profile

/// Calibrated roofline: measure what this machine can do (STREAM-triad
/// bandwidth, peak MAC throughput), then drive the resolved kernel's two
/// phases — Psumbook build and gather — with separate [`Counters`] and
/// place their exact byte/MAC attribution under the measured roofs.
fn cmd_profile(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "profile",
        "calibrate machine peaks; place the kernel's exact counters under the roofline",
    )
    .opt("config", Some("m1v4g128"), "quant config label (e.g. m2v8g128)")
    .opt("n", Some("1024"), "rows")
    .opt("k", Some("1024"), "cols")
    .opt("batch", Some("1"), "batch columns")
    .opt("kernel-impl", Some("auto"), "auto | scalar | unrolled | avx2")
    .opt("simd-lanes", Some("0"), "0 = auto, 1 = scalar, 8 or 16 unrolled lanes")
    .flag("quick", "fast calibration (fewer reps, capped sweep buffer) for CI smoke runs");
    let m = cmd.parse(args)?;
    let qcfg = QuantConfig::parse_label(m.str("config")?)?;
    let (n, k, mb) = (m.usize("n")?, m.usize("k")?, m.usize("batch")?);
    let quick = m.flag("quick");
    let impl_arg = m.str("kernel-impl")?;
    let kernel_impl = KernelImpl::parse(impl_arg).ok_or_else(|| {
        anyhow::anyhow!("--kernel-impl expects auto|scalar|unrolled|avx2, got '{impl_arg}'")
    })?;
    let kernel = KernelConfig {
        kernel_impl,
        simd_lanes: m.usize("simd-lanes")?,
        ..KernelConfig::default()
    };

    // 1. Machine calibration: cache hierarchy + attainable peaks.
    let caches = CacheSizes::detect();
    println!(
        "caches:  L1d {} KiB, L2 {} KiB, LLC {} KiB",
        caches.l1d >> 10,
        caches.l2 >> 10,
        caches.llc >> 10
    );
    println!("calibrating peaks ({}) …", if quick { "quick" } else { "full" });
    let peaks = calibrate(&caches, quick);
    println!(
        "peaks:   {:.2} GB/s bandwidth (STREAM triad), {:.2} GMAC/s compute",
        peaks.bw_gbs, peaks.gmacs
    );

    // 2. Drive the kernel's phases with separate counters — the same
    //    exact byte/MAC attribution the serving metrics use.
    let w = Prng::seeded(1).normal_vec(n * k, 0.02);
    let q = Quantizer::new(qcfg).quantize(&w, n, k);
    let engine = CodeGemmEngine::with_kernel(&q, kernel);
    let sel = engine.kernel_sel();
    println!("kernel:  {} ({} lanes) on {n}×{k} {}, batch {mb}", sel.label(), sel.lanes, qcfg.label());

    let x = Prng::seeded(2).normal_vec(k * mb, 1.0);
    let tile_w = engine.kernel_config().tile_w;
    let reps = if quick { 2 } else { 8 };
    let mut build_c = Counters::new();
    let mut gather_c = Counters::new();
    let mut book = Psumbook::default();
    let mut buf: Vec<f32> = Vec::new();
    let mut y = vec![0.0f32; n * mb];
    for _ in 0..reps {
        y.iter_mut().for_each(|v| *v = 0.0);
        let mut c0 = 0;
        while c0 < k {
            let c1 = (c0 + tile_w).min(k);
            engine.build_book(&x, mb, c0, c1, &mut book, &mut buf, &mut build_c);
            let t0 = std::time::Instant::now();
            engine.gather_into(&book, c0, mb, &mut y, &mut gather_c);
            gather_c.read_seconds += t0.elapsed().as_secs_f64();
            c0 = c1;
        }
    }
    std::hint::black_box(&y);

    // 3. Place each phase under the roofs.
    let build_pt = analyze("build", build_c.build_ops, build_c.build_bytes, build_c.build_seconds, &peaks);
    let gather_pt = analyze("gather", gather_c.read_ops, gather_c.read_bytes, gather_c.read_seconds, &peaks);
    for p in [&build_pt, &gather_pt] {
        println!(
            "{:>7}: {:.2} GB/s, {:.2} GMAC/s achieved — AI {:.2} MAC/B, {}-bound, \
             attainable {:.2} GMAC/s ({:.0}% reached)",
            p.phase, p.achieved_gbs, p.achieved_gmacs, p.intensity, p.bound, p.attainable_gmacs,
            p.pct_attainable
        );
    }

    // 4. Working-set audit: does the hot state fit on-chip?
    let audit = FootprintAudit::new(book.data.capacity() * 4, 0, buf.capacity() * 4, &caches);
    println!(
        "footprint: {} KiB working set (book {} KiB, staging {} KiB) — fits {}",
        audit.total_bytes >> 10,
        audit.book_bytes >> 10,
        audit.staging_bytes >> 10,
        audit.level
    );
    Ok(())
}

// ----------------------------------------------------------------- doctor

fn cmd_doctor(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("doctor", "environment self-checks")
        .opt("artifacts", Some("artifacts"), "artifacts dir");
    let m = cmd.parse(args)?;
    print!("PJRT CPU client … ");
    match pjrt_self_test() {
        Ok(()) => println!("ok"),
        Err(e) => println!("FAILED: {e:#}"),
    }
    let dir = Path::new(m.str("artifacts")?);
    print!("artifacts at {} … ", dir.display());
    if dir.join("manifest.json").exists() {
        match ModelRuntime::load(dir) {
            Ok(rt) => println!(
                "ok (engine {}, batches {:?}, {} weight tensors)",
                rt.manifest.engine,
                rt.batch_sizes(),
                rt.manifest.weight_args.len()
            ),
            Err(e) => println!("FAILED to load: {e:#}"),
        }
    } else {
        println!("absent — run `make artifacts`");
    }
    print!("simulator calibration … ");
    let sim = codegemm::simulator::Simulator::a100();
    let worst = sim.fit_rmse.values().cloned().fold(0.0f64, f64::max);
    println!("ok (worst family rel-RMSE {:.1}%)", 100.0 * worst);
    Ok(())
}
