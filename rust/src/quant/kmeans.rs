//! Weighted k-means clustering over length-`v` vectors (paper §2.2 Step 2).
//!
//! k-means++ initialization, Lloyd iterations with empty-cluster
//! reseeding, optional per-point importance weights (used by the
//! calibration-aware quantizer), and deterministic behaviour from a seed.

use crate::util::prng::Prng;

/// Result of a k-means run.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    /// `centroids[i * dim .. (i+1) * dim]` is centroid `i`.
    pub centroids: Vec<f32>,
    /// Assignment of each input point to a centroid index.
    pub assignments: Vec<u32>,
    /// Final weighted sum of squared distances.
    pub inertia: f64,
    pub iterations: usize,
}

/// Options for a k-means run.
#[derive(Clone, Copy, Debug)]
pub struct KMeansOptions {
    pub n_clusters: usize,
    pub dim: usize,
    pub max_iters: usize,
    pub seed: u64,
    /// Relative inertia improvement below which iteration stops.
    pub tol: f64,
}

impl KMeansOptions {
    pub fn new(n_clusters: usize, dim: usize) -> KMeansOptions {
        KMeansOptions { n_clusters, dim, max_iters: 12, seed: 0xC0DE, tol: 1e-4 }
    }
}

#[inline]
fn dist2(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// k-means++ seeding: first centroid uniform, subsequent ones proportional
/// to (weighted) squared distance to the nearest chosen centroid.
fn init_plusplus(points: &[f32], weights: Option<&[f32]>, opts: &KMeansOptions, rng: &mut Prng) -> Vec<f32> {
    let d = opts.dim;
    let n = points.len() / d;
    let kc = opts.n_clusters.min(n.max(1));
    let mut centroids = Vec::with_capacity(opts.n_clusters * d);
    let first = rng.index(n);
    centroids.extend_from_slice(&points[first * d..(first + 1) * d]);
    let mut best_d2: Vec<f64> = (0..n)
        .map(|p| {
            let w = weights.map(|w| w[p] as f64).unwrap_or(1.0);
            dist2(&points[p * d..(p + 1) * d], &centroids[..d]) as f64 * w
        })
        .collect();
    while centroids.len() / d < kc {
        let total: f64 = best_d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.index(n)
        } else {
            let mut t = rng.uniform() * total;
            let mut pick = n - 1;
            for (p, d2) in best_d2.iter().enumerate() {
                t -= d2;
                if t <= 0.0 {
                    pick = p;
                    break;
                }
            }
            pick
        };
        let c0 = centroids.len();
        centroids.extend_from_slice(&points[pick * d..(pick + 1) * d]);
        let new_c = &centroids[c0..c0 + d];
        for p in 0..n {
            let w = weights.map(|w| w[p] as f64).unwrap_or(1.0);
            let nd = dist2(&points[p * d..(p + 1) * d], new_c) as f64 * w;
            if nd < best_d2[p] {
                best_d2[p] = nd;
            }
        }
    }
    // If fewer points than clusters, duplicate-with-jitter to fill.
    while centroids.len() / d < opts.n_clusters {
        let src = rng.index(centroids.len() / d);
        let mut c: Vec<f32> = centroids[src * d..(src + 1) * d].to_vec();
        for x in c.iter_mut() {
            *x += rng.normal_f32() * 1e-4;
        }
        centroids.extend_from_slice(&c);
    }
    centroids
}

/// Assign each point to its nearest centroid; returns (assignments,
/// weighted inertia).
pub fn assign(points: &[f32], centroids: &[f32], dim: usize, weights: Option<&[f32]>) -> (Vec<u32>, f64) {
    let n = points.len() / dim;
    let kc = centroids.len() / dim;
    let mut asg = vec![0u32; n];
    let mut inertia = 0f64;
    for p in 0..n {
        let pt = &points[p * dim..(p + 1) * dim];
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for c in 0..kc {
            let d2 = dist2(pt, &centroids[c * dim..(c + 1) * dim]);
            if d2 < best_d {
                best_d = d2;
                best = c;
            }
        }
        asg[p] = best as u32;
        let w = weights.map(|w| w[p] as f64).unwrap_or(1.0);
        inertia += best_d as f64 * w;
    }
    (asg, inertia)
}

/// Recompute centroids as the weighted mean of their members. Empty
/// clusters are reseeded to the point farthest from its centroid.
fn update_centroids(
    points: &[f32],
    asg: &[u32],
    weights: Option<&[f32]>,
    opts: &KMeansOptions,
    rng: &mut Prng,
    centroids: &mut [f32],
) {
    let d = opts.dim;
    let n = points.len() / d;
    let kc = opts.n_clusters;
    let mut sums = vec![0f64; kc * d];
    let mut wsum = vec![0f64; kc];
    for p in 0..n {
        let c = asg[p] as usize;
        let w = weights.map(|w| w[p] as f64).unwrap_or(1.0);
        wsum[c] += w;
        for t in 0..d {
            sums[c * d + t] += points[p * d + t] as f64 * w;
        }
    }
    for c in 0..kc {
        if wsum[c] > 0.0 {
            for t in 0..d {
                centroids[c * d + t] = (sums[c * d + t] / wsum[c]) as f32;
            }
        } else if n > 0 {
            // Reseed empty cluster at a random point (weighted draw keeps
            // determinism through the shared rng).
            let p = rng.index(n);
            centroids[c * d..(c + 1) * d].copy_from_slice(&points[p * d..(p + 1) * d]);
        }
    }
}

/// Run weighted k-means. `points` is `n*dim` flat; `weights` optional
/// per-point importance (defaults to 1).
pub fn kmeans(points: &[f32], weights: Option<&[f32]>, opts: KMeansOptions) -> KMeansResult {
    assert!(opts.dim > 0 && points.len() % opts.dim == 0, "bad points length");
    let n = points.len() / opts.dim;
    assert!(n > 0, "kmeans on empty point set");
    if let Some(w) = weights {
        assert_eq!(w.len(), n);
    }
    let mut rng = Prng::seeded(opts.seed);
    let mut centroids = init_plusplus(points, weights, &opts, &mut rng);
    let (mut asg, mut inertia) = assign(points, &centroids, opts.dim, weights);
    let mut iters = 0;
    for _ in 0..opts.max_iters {
        iters += 1;
        update_centroids(points, &asg, weights, &opts, &mut rng, &mut centroids);
        let (new_asg, new_inertia) = assign(points, &centroids, opts.dim, weights);
        let improved = inertia - new_inertia;
        asg = new_asg;
        let prev = inertia;
        inertia = new_inertia;
        if improved <= opts.tol * prev.max(1e-12) {
            break;
        }
    }
    KMeansResult { centroids, assignments: asg, inertia, iterations: iters }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated blobs in 2D.
    fn blobs(rng: &mut Prng, per: usize) -> Vec<f32> {
        let centers = [(-5.0f32, 0.0f32), (5.0, 0.0), (0.0, 8.0)];
        let mut pts = Vec::new();
        for &(cx, cy) in &centers {
            for _ in 0..per {
                pts.push(cx + rng.normal_f32() * 0.3);
                pts.push(cy + rng.normal_f32() * 0.3);
            }
        }
        pts
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Prng::seeded(1);
        let pts = blobs(&mut rng, 50);
        let res = kmeans(&pts, None, KMeansOptions { max_iters: 30, ..KMeansOptions::new(3, 2) });
        // Every centroid should be near one of the true centers.
        let centers = [(-5.0f32, 0.0f32), (5.0, 0.0), (0.0, 8.0)];
        for c in 0..3 {
            let cx = res.centroids[c * 2];
            let cy = res.centroids[c * 2 + 1];
            let ok = centers.iter().any(|&(x, y)| ((cx - x).powi(2) + (cy - y).powi(2)).sqrt() < 1.0);
            assert!(ok, "centroid ({cx},{cy}) not near any blob center");
        }
        // Inertia per point should be tiny relative to blob separation.
        assert!(res.inertia / 150.0 < 0.5, "inertia {}", res.inertia);
    }

    #[test]
    fn deterministic_from_seed() {
        let mut rng = Prng::seeded(2);
        let pts = blobs(&mut rng, 20);
        let a = kmeans(&pts, None, KMeansOptions::new(4, 2));
        let b = kmeans(&pts, None, KMeansOptions::new(4, 2));
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn inertia_decreases_with_more_clusters() {
        let mut rng = Prng::seeded(3);
        let pts: Vec<f32> = (0..400).map(|_| rng.normal_f32()).collect();
        let i2 = kmeans(&pts, None, KMeansOptions::new(2, 2)).inertia;
        let i8 = kmeans(&pts, None, KMeansOptions::new(8, 2)).inertia;
        assert!(i8 < i2, "k=8 ({i8}) should beat k=2 ({i2})");
    }

    #[test]
    fn handles_more_clusters_than_points() {
        let pts = vec![0.0f32, 0.0, 1.0, 1.0]; // 2 points in 2D
        let res = kmeans(&pts, None, KMeansOptions::new(8, 2));
        assert_eq!(res.centroids.len(), 8 * 2);
        assert!(res.assignments.iter().all(|&a| a < 8));
    }

    #[test]
    fn weights_pull_centroids() {
        // Two points; give one a huge weight — with k=1 the centroid must
        // sit nearly on the heavy point.
        let pts = vec![0.0f32, 0.0, 10.0, 0.0];
        let w = vec![1.0f32, 1000.0];
        let res = kmeans(&pts, Some(&w), KMeansOptions::new(1, 2));
        assert!((res.centroids[0] - 10.0).abs() < 0.1, "centroid at {}", res.centroids[0]);
    }

    #[test]
    fn assignments_are_nearest() {
        let mut rng = Prng::seeded(4);
        let pts = blobs(&mut rng, 10);
        let res = kmeans(&pts, None, KMeansOptions::new(3, 2));
        let (re_asg, _) = assign(&pts, &res.centroids, 2, None);
        assert_eq!(res.assignments, re_asg);
    }

    #[test]
    fn single_cluster_is_mean() {
        let pts = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 pts in 2D
        let res = kmeans(&pts, None, KMeansOptions::new(1, 2));
        assert!((res.centroids[0] - 3.0).abs() < 1e-5);
        assert!((res.centroids[1] - 4.0).abs() < 1e-5);
    }
}
