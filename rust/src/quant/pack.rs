//! Bit-packing of code indices (paper stores `b`-bit codes densely).
//!
//! Codes are packed LSB-first into a little-endian bitstream. For `b = 8`
//! (the paper's recommended setting) a zero-copy `u8` fast path is kept so
//! the GEMM hot loop can index codes directly without bit arithmetic.

use anyhow::{bail, Result};

/// Densely bit-packed code array.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedCodes {
    bits: usize,
    len: usize,
    data: Vec<u8>,
}

impl PackedCodes {
    /// Pack `codes` (each `< 2^bits`) into a bitstream.
    pub fn pack(codes: &[u32], bits: usize) -> Result<PackedCodes> {
        if bits == 0 || bits > 16 {
            bail!("bits must be in [1,16], got {bits}");
        }
        let limit = 1u32 << bits;
        let mut data = vec![0u8; (codes.len() * bits).div_ceil(8)];
        for (i, &c) in codes.iter().enumerate() {
            if c >= limit {
                bail!("code {c} out of range for {bits} bits");
            }
            let bit0 = i * bits;
            let mut remaining = bits;
            let mut val = c;
            let mut pos = bit0;
            while remaining > 0 {
                let byte = pos / 8;
                let off = pos % 8;
                let take = remaining.min(8 - off);
                let mask = ((1u32 << take) - 1) as u8;
                data[byte] |= (((val as u8) & mask) as u8) << off;
                val >>= take;
                pos += take;
                remaining -= take;
            }
        }
        Ok(PackedCodes { bits, len: codes.len(), data })
    }

    /// Number of stored codes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Raw packed bytes (for serialization / the AOT export parity tests).
    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    /// Construct from raw packed bytes.
    pub fn from_bytes(data: Vec<u8>, bits: usize, len: usize) -> Result<PackedCodes> {
        if bits == 0 || bits > 16 {
            bail!("bits must be in [1,16]");
        }
        if data.len() < (len * bits).div_ceil(8) {
            bail!("packed data too short: {} bytes for {len} codes of {bits} bits", data.len());
        }
        Ok(PackedCodes { bits, len, data })
    }

    /// Read code `i`.
    #[inline]
    pub fn get(&self, i: usize) -> usize {
        debug_assert!(i < self.len);
        let bit0 = i * self.bits;
        let mut val = 0u32;
        let mut got = 0usize;
        let mut pos = bit0;
        while got < self.bits {
            let byte = pos / 8;
            let off = pos % 8;
            let take = (self.bits - got).min(8 - off);
            let mask = ((1u32 << take) - 1) as u32;
            val |= (((self.data[byte] as u32) >> off) & mask) << got;
            got += take;
            pos += take;
        }
        val as usize
    }

    /// Unpack everything to u32.
    pub fn unpack(&self) -> Vec<u32> {
        (0..self.len).map(|i| self.get(i) as u32).collect()
    }

    /// Unpack to u8 (requires `bits <= 8`); the GEMM fast path operates on
    /// this representation.
    pub fn unpack_u8(&self) -> Result<Vec<u8>> {
        if self.bits > 8 {
            bail!("unpack_u8 requires bits <= 8 (got {})", self.bits);
        }
        // b == 8 is the no-op fast path.
        if self.bits == 8 {
            return Ok(self.data[..self.len].to_vec());
        }
        Ok((0..self.len).map(|i| self.get(i) as u8).collect())
    }

    /// Largest stored code value (0 for empty).
    pub fn max_value(&self) -> usize {
        (0..self.len).map(|i| self.get(i)).max().unwrap_or(0)
    }

    /// Packed size in bytes.
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Prng::seeded(1);
        for bits in 1..=16usize {
            let limit = 1u32 << bits;
            let codes: Vec<u32> = (0..257).map(|_| rng.next_u32() % limit).collect();
            let packed = PackedCodes::pack(&codes, bits).unwrap();
            assert_eq!(packed.unpack(), codes, "bits={bits}");
        }
    }

    #[test]
    fn b8_is_byte_identical() {
        let codes: Vec<u32> = (0..=255).collect();
        let packed = PackedCodes::pack(&codes, 8).unwrap();
        assert_eq!(packed.bytes().len(), 256);
        assert_eq!(packed.unpack_u8().unwrap(), (0..=255).collect::<Vec<u8>>());
    }

    #[test]
    fn packed_size_is_minimal() {
        let codes = vec![1u32; 100];
        for bits in [1usize, 2, 3, 5, 8, 12] {
            let packed = PackedCodes::pack(&codes, bits).unwrap();
            assert_eq!(packed.packed_bytes(), (100 * bits).div_ceil(8));
        }
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(PackedCodes::pack(&[4], 2).is_err());
        assert!(PackedCodes::pack(&[3], 2).is_ok());
        assert!(PackedCodes::pack(&[0], 0).is_err());
        assert!(PackedCodes::pack(&[0], 17).is_err());
    }

    #[test]
    fn unpack_u8_rejects_wide() {
        let packed = PackedCodes::pack(&[1000], 12).unwrap();
        assert!(packed.unpack_u8().is_err());
    }

    #[test]
    fn from_bytes_validates_length() {
        let packed = PackedCodes::pack(&[1, 2, 3], 8).unwrap();
        let bytes = packed.bytes().to_vec();
        assert!(PackedCodes::from_bytes(bytes.clone(), 8, 3).is_ok());
        assert!(PackedCodes::from_bytes(bytes.clone(), 8, 4).is_err());
        let back = PackedCodes::from_bytes(bytes, 8, 3).unwrap();
        assert_eq!(back.unpack(), vec![1, 2, 3]);
    }

    #[test]
    fn max_value_scan() {
        let packed = PackedCodes::pack(&[3, 7, 1], 4).unwrap();
        assert_eq!(packed.max_value(), 7);
    }

    #[test]
    fn crossing_byte_boundaries() {
        // 3-bit codes cross byte boundaries at every third code.
        let codes: Vec<u32> = (0..64).map(|i| (i * 5) % 8).collect();
        let packed = PackedCodes::pack(&codes, 3).unwrap();
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(packed.get(i) as u32, c, "index {i}");
        }
    }
}
