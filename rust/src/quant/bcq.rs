//! Binary-Coded Quantization (BCQ) — the weight format consumed by the
//! LUT-GEMM baseline kernel (paper refs [20, 30]).
//!
//! Each group of `g` weights in a row is approximated by a sum of `q`
//! binary vectors with per-vector scales: `w ≈ Σ_{i<q} α_i · b_i`,
//! `b_i ∈ {−1, +1}^g`. Greedy alternating fit: `b_i = sign(residual)`,
//! `α_i = mean(|residual|)`, which is the closed-form 1-term optimum.

use crate::util::f16::round_f16;
use anyhow::{bail, Result};

/// BCQ-quantized linear layer.
#[derive(Clone, Debug)]
pub struct BcqLinear {
    pub n: usize,
    pub k: usize,
    /// Number of binary components (effective bits per weight, excl. scales).
    pub q_bits: usize,
    pub group: usize,
    /// Sign bitplanes: `bits[i][r * k + c]` packed as u64 words per plane.
    /// Plane i, row r: bit c of word `(r * words_per_row) + c/64`.
    planes: Vec<Vec<u64>>,
    /// Scales α: `alphas[((r * n_groups) + gi) * q_bits + i]`, f16.
    pub alphas: Vec<f32>,
}

impl BcqLinear {
    pub fn quantize(w: &[f32], n: usize, k: usize, q_bits: usize, group: usize) -> Result<BcqLinear> {
        if q_bits == 0 || q_bits > 8 {
            bail!("q_bits must be in [1, 8]");
        }
        let group = group.min(k).max(1);
        if k % group != 0 {
            bail!("k must be a multiple of group");
        }
        assert_eq!(w.len(), n * k);
        let n_groups = k / group;
        let words_per_row = k.div_ceil(64);
        let mut planes = vec![vec![0u64; n * words_per_row]; q_bits];
        let mut alphas = vec![0f32; n * n_groups * q_bits];
        let mut residual = vec![0f32; group];
        for r in 0..n {
            for gi in 0..n_groups {
                let lo = gi * group;
                residual.copy_from_slice(&w[r * k + lo..r * k + lo + group]);
                for i in 0..q_bits {
                    let alpha = round_f16(residual.iter().map(|x| x.abs()).sum::<f32>() / group as f32);
                    alphas[(r * n_groups + gi) * q_bits + i] = alpha;
                    for (t, res) in residual.iter_mut().enumerate() {
                        let c = lo + t;
                        let sign = if *res >= 0.0 { 1.0 } else { -1.0 };
                        if sign > 0.0 {
                            planes[i][r * words_per_row + c / 64] |= 1u64 << (c % 64);
                        }
                        *res -= alpha * sign;
                    }
                }
            }
        }
        Ok(BcqLinear { n, k, q_bits, group, planes, alphas })
    }

    pub fn n_groups(&self) -> usize {
        self.k / self.group
    }

    #[inline]
    pub fn sign(&self, plane: usize, r: usize, c: usize) -> f32 {
        let words_per_row = self.k.div_ceil(64);
        if (self.planes[plane][r * words_per_row + c / 64] >> (c % 64)) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    #[inline]
    pub fn alpha(&self, r: usize, c: usize, plane: usize) -> f32 {
        self.alphas[(r * self.n_groups() + c / self.group) * self.q_bits + plane]
    }

    /// Raw bitplane words for row `r`, plane `i` (the LUT kernel consumes
    /// these directly).
    pub fn row_plane_words(&self, plane: usize, r: usize) -> &[u64] {
        let wpr = self.k.div_ceil(64);
        &self.planes[plane][r * wpr..(r + 1) * wpr]
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut w = vec![0f32; self.n * self.k];
        for r in 0..self.n {
            for c in 0..self.k {
                let mut acc = 0f32;
                for i in 0..self.q_bits {
                    acc += self.alpha(r, c, i) * self.sign(i, r, c);
                }
                w[r * self.k + c] = acc;
            }
        }
        w
    }

    /// Average storage bits per weight.
    pub fn bits_per_weight(&self) -> f64 {
        self.q_bits as f64 + 16.0 * self.q_bits as f64 / self.group as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::stats;

    #[test]
    fn error_decreases_with_q_bits() {
        let (n, k) = (16, 128);
        let w = Prng::seeded(1).normal_vec(n * k, 0.02);
        let err = |q| {
            let b = BcqLinear::quantize(&w, n, k, q, 128).unwrap();
            stats::rel_l2(&b.dequantize(), &w)
        };
        assert!(err(2) < err(1));
        assert!(err(4) < err(2));
    }

    #[test]
    fn one_bit_is_sign_times_mean_abs() {
        let w = vec![1.0f32, -2.0, 3.0, -4.0];
        let b = BcqLinear::quantize(&w, 1, 4, 1, 4).unwrap();
        let deq = b.dequantize();
        let alpha = (1.0 + 2.0 + 3.0 + 4.0) / 4.0;
        let expect = [alpha, -alpha, alpha, -alpha];
        for (x, e) in deq.iter().zip(expect) {
            assert!((x - e).abs() < 1e-3, "{x} vs {e}");
        }
    }

    #[test]
    fn bcq2_beats_nothing_but_tracks_signal() {
        let (n, k) = (8, 64);
        let w = Prng::seeded(2).normal_vec(n * k, 0.02);
        let b = BcqLinear::quantize(&w, n, k, 2, 64).unwrap();
        let rel = stats::rel_l2(&b.dequantize(), &w);
        assert!(rel < 0.65, "bcq-2 rel={rel}");
    }

    #[test]
    fn sign_accessor_matches_dequant() {
        let (n, k) = (4, 128);
        let w = Prng::seeded(3).normal_vec(n * k, 1.0);
        let b = BcqLinear::quantize(&w, n, k, 3, 32).unwrap();
        let deq = b.dequantize();
        for r in 0..n {
            for c in 0..k {
                let manual: f32 = (0..3).map(|i| b.alpha(r, c, i) * b.sign(i, r, c)).sum();
                assert!((manual - deq[r * k + c]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn footprint() {
        let w = vec![0.5f32; 256];
        let b = BcqLinear::quantize(&w, 2, 128, 2, 128).unwrap();
        assert!((b.bits_per_weight() - 2.25).abs() < 1e-9);
    }

    #[test]
    fn rejects_bad_params() {
        let w = vec![0f32; 16];
        assert!(BcqLinear::quantize(&w, 4, 4, 0, 4).is_err());
        assert!(BcqLinear::quantize(&w, 4, 4, 9, 4).is_err());
        assert!(BcqLinear::quantize(&w, 4, 4, 2, 3).is_err());
    }
}
