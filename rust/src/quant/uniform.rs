//! Uniform (integer) group-scaled quantization — the GPTQ / FlexRound /
//! AWQ format class used as a baseline in the paper's Tables 4 and 5.
//!
//! Each group of `g` consecutive weights in a row shares an FP16 scale;
//! weights are rounded to signed integers in `[-2^(b-1), 2^(b-1)-1]`
//! (asymmetric zero-point omitted: Llama weights are near-zero-mean, and
//! the paper's baselines are symmetric RTN-class quantizers).

use crate::util::f16::round_f16;
use anyhow::{bail, Result};

/// A uniformly quantized linear layer.
#[derive(Clone, Debug)]
pub struct UniformLinear {
    pub n: usize,
    pub k: usize,
    pub bits: usize,
    pub group: usize,
    /// Quantized integer weights, row-major, stored widened to i8.
    pub qweight: Vec<i8>,
    /// FP16 scales per (row, group).
    pub scales: Vec<f32>,
}

impl UniformLinear {
    /// Round-to-nearest quantization of a row-major `n×k` matrix.
    pub fn quantize(w: &[f32], n: usize, k: usize, bits: usize, group: usize) -> Result<UniformLinear> {
        if !(2..=8).contains(&bits) {
            bail!("uniform bits must be in [2, 8], got {bits}");
        }
        let group = group.min(k).max(1);
        if k % group != 0 {
            bail!("k ({k}) must be a multiple of group ({group})");
        }
        assert_eq!(w.len(), n * k);
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let n_groups = k / group;
        let mut qweight = vec![0i8; n * k];
        let mut scales = vec![1f32; n * n_groups];
        for r in 0..n {
            for gi in 0..n_groups {
                let lo = gi * group;
                let hi = lo + group;
                let mut amax = 0f32;
                for c in lo..hi {
                    amax = amax.max(w[r * k + c].abs());
                }
                let scale = if amax > 0.0 { round_f16(amax / qmax) } else { 1.0 };
                let scale = if scale == 0.0 { 1.0 } else { scale };
                scales[r * n_groups + gi] = scale;
                for c in lo..hi {
                    let q = (w[r * k + c] / scale).round().clamp(-qmax - 1.0, qmax);
                    qweight[r * k + c] = q as i8;
                }
            }
        }
        Ok(UniformLinear { n, k, bits, group, qweight, scales })
    }

    pub fn n_groups(&self) -> usize {
        self.k / self.group
    }

    #[inline]
    pub fn scale(&self, r: usize, col: usize) -> f32 {
        self.scales[r * self.n_groups() + col / self.group]
    }

    /// Reconstruct the dequantized matrix.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut w = vec![0f32; self.n * self.k];
        for r in 0..self.n {
            for c in 0..self.k {
                w[r * self.k + c] = self.qweight[r * self.k + c] as f32 * self.scale(r, c);
            }
        }
        w
    }

    /// Average storage bits per weight (packed ints + FP16 scales).
    pub fn bits_per_weight(&self) -> f64 {
        self.bits as f64 + 16.0 / self.group as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::stats;

    #[test]
    fn four_bit_error_is_small() {
        let (n, k) = (32, 128);
        let w = Prng::seeded(1).normal_vec(n * k, 0.02);
        let q = UniformLinear::quantize(&w, n, k, 4, 128).unwrap();
        let rel = stats::rel_l2(&q.dequantize(), &w);
        assert!(rel < 0.12, "4-bit rel={rel}");
    }

    #[test]
    fn error_ordering_by_bits() {
        let (n, k) = (32, 128);
        let w = Prng::seeded(2).normal_vec(n * k, 0.02);
        let err = |bits| {
            let q = UniformLinear::quantize(&w, n, k, bits, 128).unwrap();
            stats::rel_l2(&q.dequantize(), &w)
        };
        assert!(err(8) < err(4));
        assert!(err(4) < err(3));
        assert!(err(3) < err(2));
    }

    #[test]
    fn two_bit_is_bad_exactly_as_paper_argues() {
        // The paper's motivation: uniform 2-bit collapses. Relative error
        // should be large (>25%) on gaussian weights.
        let (n, k) = (32, 128);
        let w = Prng::seeded(3).normal_vec(n * k, 0.02);
        let q = UniformLinear::quantize(&w, n, k, 2, 128).unwrap();
        let rel = stats::rel_l2(&q.dequantize(), &w);
        assert!(rel > 0.25, "2-bit uniform should hurt, rel={rel}");
    }

    #[test]
    fn qweight_within_range() {
        let (n, k) = (8, 64);
        let w = Prng::seeded(4).normal_vec(n * k, 10.0);
        for bits in [2usize, 3, 4, 8] {
            let q = UniformLinear::quantize(&w, n, k, bits, 32).unwrap();
            let lim = 1i32 << (bits - 1);
            for &x in &q.qweight {
                assert!((x as i32) >= -lim && (x as i32) < lim, "bits={bits} x={x}");
            }
        }
    }

    #[test]
    fn footprint_formula() {
        let (n, k) = (8, 256);
        let w = Prng::seeded(5).normal_vec(n * k, 1.0);
        let q = UniformLinear::quantize(&w, n, k, 2, 128).unwrap();
        assert!((q.bits_per_weight() - 2.125).abs() < 1e-9);
    }

    #[test]
    fn rejects_bad_params() {
        let w = vec![0f32; 64];
        assert!(UniformLinear::quantize(&w, 8, 8, 1, 8).is_err());
        assert!(UniformLinear::quantize(&w, 8, 8, 9, 8).is_err());
        assert!(UniformLinear::quantize(&w, 8, 8, 4, 3).is_err());
    }

    #[test]
    fn zero_matrix_is_exact() {
        let w = vec![0f32; 64];
        let q = UniformLinear::quantize(&w, 8, 8, 2, 8).unwrap();
        assert_eq!(q.dequantize(), w);
    }
}
