//! Group normalization (paper §2.2 Step 1).
//!
//! Each row of the weight matrix is divided into groups of `g` consecutive
//! elements (`g = -1` ⇒ the whole row); every group is normalized by its
//! absolute maximum so the resulting vectors live in `[-1, 1]^v`, which is
//! what the shared codebooks are trained on. Scales are stored in FP16.

use crate::config::QuantConfig;
use crate::util::f16::round_f16;

/// Per-(row, group) scales for an `n×k` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupScales {
    pub n: usize,
    pub k: usize,
    pub g: usize,
    /// `scales[r * n_groups + gi]`
    pub scales: Vec<f32>,
}

impl GroupScales {
    pub fn n_groups(&self) -> usize {
        self.k.div_ceil(self.g)
    }

    #[inline]
    pub fn at(&self, r: usize, col: usize) -> f32 {
        self.scales[r * self.n_groups() + col / self.g]
    }

    /// Compute absmax scales for `w` under `cfg`, returning scales and the
    /// normalized matrix. Zero groups get scale 1 (nothing to normalize).
    pub fn compute(w: &[f32], n: usize, k: usize, cfg: &QuantConfig) -> (GroupScales, Vec<f32>) {
        let g = cfg.group_size(k);
        let n_groups = k.div_ceil(g);
        let mut scales = vec![1f32; n * n_groups];
        let mut normalized = vec![0f32; n * k];
        for r in 0..n {
            for gi in 0..n_groups {
                let lo = gi * g;
                let hi = ((gi + 1) * g).min(k);
                let mut amax = 0f32;
                for c in lo..hi {
                    amax = amax.max(w[r * k + c].abs());
                }
                // f16-round the scale (it is stored in FP16 on device).
                let s = if amax > 0.0 { round_f16(amax) } else { 1.0 };
                let s = if s == 0.0 { 1.0 } else { s }; // f16 underflow guard
                scales[r * n_groups + gi] = s;
                let inv = 1.0 / s;
                for c in lo..hi {
                    normalized[r * k + c] = w[r * k + c] * inv;
                }
            }
        }
        (GroupScales { n, k, g, scales }, normalized)
    }

    /// Apply scales to a normalized matrix (inverse of `compute`'s
    /// normalization, up to f16 rounding of the scales).
    pub fn denormalize(&self, normalized: &[f32]) -> Vec<f32> {
        let mut w = vec![0f32; self.n * self.k];
        for r in 0..self.n {
            for c in 0..self.k {
                w[r * self.k + c] = normalized[r * self.k + c] * self.at(r, c);
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::stats;

    #[test]
    fn roundtrip_up_to_f16_scale_rounding() {
        let (n, k) = (8, 64);
        let w = Prng::seeded(1).normal_vec(n * k, 1.0);
        let cfg = QuantConfig::new(4, 1, 8, 16).unwrap();
        let (scales, norm) = GroupScales::compute(&w, n, k, &cfg);
        let back = scales.denormalize(&norm);
        // Normalization divides by f16(amax) and denormalize multiplies by
        // the same stored value, so the roundtrip is exact in f32 terms.
        assert!(stats::max_abs_diff(&back, &w) < 1e-6);
    }

    #[test]
    fn normalized_values_bounded() {
        let (n, k) = (4, 32);
        let w = Prng::seeded(2).normal_vec(n * k, 5.0);
        let cfg = QuantConfig::new(4, 1, 8, 8).unwrap();
        let (_, norm) = GroupScales::compute(&w, n, k, &cfg);
        // |w|/f16(amax) can exceed 1 by at most the f16 rounding (2^-11).
        for x in norm {
            assert!(x.abs() <= 1.0 + 1e-3, "{x}");
        }
    }

    #[test]
    fn row_wise_when_g_is_none() {
        let (n, k) = (2, 16);
        let w = Prng::seeded(3).normal_vec(n * k, 1.0);
        let cfg = QuantConfig::new(4, 1, 8, -1).unwrap();
        let (scales, _) = GroupScales::compute(&w, n, k, &cfg);
        assert_eq!(scales.n_groups(), 1);
        assert_eq!(scales.scales.len(), n);
    }

    #[test]
    fn zero_group_scale_is_one() {
        let (n, k) = (1, 8);
        let w = vec![0f32; n * k];
        let cfg = QuantConfig::new(4, 1, 8, 4).unwrap();
        let (scales, norm) = GroupScales::compute(&w, n, k, &cfg);
        assert!(scales.scales.iter().all(|&s| s == 1.0));
        assert!(norm.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scales_are_f16_values() {
        let (n, k) = (4, 16);
        let w = Prng::seeded(4).normal_vec(n * k, 0.37);
        let cfg = QuantConfig::new(4, 1, 8, 8).unwrap();
        let (scales, _) = GroupScales::compute(&w, n, k, &cfg);
        for &s in &scales.scales {
            assert_eq!(s, round_f16(s));
        }
    }

    #[test]
    fn at_indexes_correct_group() {
        let (n, k) = (2, 8);
        #[rustfmt::skip]
        let w = vec![
            1.0, 1.0, 2.0, 2.0, 4.0, 4.0, 8.0, 8.0,
            3.0, 3.0, 3.0, 3.0, 3.0, 3.0, 3.0, 3.0,
        ];
        let cfg = QuantConfig::new(2, 1, 8, 2).unwrap();
        let (scales, _) = GroupScales::compute(&w, n, k, &cfg);
        assert_eq!(scales.at(0, 0), 1.0);
        assert_eq!(scales.at(0, 2), 2.0);
        assert_eq!(scales.at(0, 5), 4.0);
        assert_eq!(scales.at(0, 7), 8.0);
        assert_eq!(scales.at(1, 3), 3.0);
    }
}
