//! Codebook-based weight quantization toolkit (paper §2.2, Figure 2).
//!
//! Pipeline: group-normalize the weight matrix → split rows into length-`v`
//! vectors → train `m` additive codebooks by residual k-means → encode each
//! vector as `m` codes of `b` bits → optionally refine codes+codebooks by
//! alternating least squares (the PV-Tuning-class post-optimization).
//!
//! Also provides the baseline formats used in the paper's evaluation:
//! uniform group-scaled quantization (GPTQ / FlexRound class) and
//! binary-coded quantization (LUT-GEMM's BCQ format).

pub mod additive;
pub mod bcq;
pub mod calib;
pub mod footprint;
pub mod kmeans;
pub mod normalize;
pub mod pack;
pub mod uniform;

pub use additive::{AdditiveQuantizer, RefineOptions};
pub use footprint::{bits_per_weight, FootprintBreakdown};
pub use normalize::GroupScales;
pub use pack::PackedCodes;

use crate::config::QuantConfig;
use crate::util::f16::round_f16;
use anyhow::{bail, Result};

/// A codebook-quantized linear layer `W (n × k)` in the paper's format.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub cfg: QuantConfig,
    pub n: usize,
    pub k: usize,
    /// `m` codebooks, flattened: `codebooks[c * 2^b * v + i * v + t]` is
    /// element `t` of centroid `i` of codebook `c`. Values are f16-rounded.
    pub codebooks: Vec<f32>,
    /// Bit-packed codes in `[r][j][c]` order (row, vector index, codebook).
    pub codes: PackedCodes,
    /// Group scales, `scales[r * n_groups + gi]`, f16-rounded.
    pub scales: Vec<f32>,
}

impl QuantizedLinear {
    /// Number of length-`v` vectors per row.
    pub fn vectors_per_row(&self) -> usize {
        self.k / self.cfg.v
    }

    /// Number of normalization groups per row.
    pub fn groups_per_row(&self) -> usize {
        let g = self.cfg.group_size(self.k);
        self.k.div_ceil(g)
    }

    /// Centroid slice for codebook `c`, code `i`.
    #[inline]
    pub fn centroid(&self, c: usize, i: usize) -> &[f32] {
        let v = self.cfg.v;
        let base = (c * self.cfg.n_centroids() + i) * v;
        &self.codebooks[base..base + v]
    }

    /// Code for (row, vector, codebook).
    #[inline]
    pub fn code(&self, r: usize, j: usize, c: usize) -> usize {
        let idx = (r * self.vectors_per_row() + j) * self.cfg.m + c;
        self.codes.get(idx)
    }

    /// Scale for (row, column).
    #[inline]
    pub fn scale(&self, r: usize, col: usize) -> f32 {
        let g = self.cfg.group_size(self.k);
        self.scales[r * self.groups_per_row() + col / g]
    }

    /// Reconstruct the full dequantized weight matrix (row-major n×k).
    /// This is the reference the GEMM engines are validated against.
    pub fn dequantize(&self) -> Vec<f32> {
        let v = self.cfg.v;
        let jn = self.vectors_per_row();
        let mut w = vec![0f32; self.n * self.k];
        for r in 0..self.n {
            for j in 0..jn {
                let col0 = j * v;
                let s = self.scale(r, col0);
                for c in 0..self.cfg.m {
                    let cent = self.centroid(c, self.code(r, j, c));
                    for t in 0..v {
                        w[r * self.k + col0 + t] += s * cent[t];
                    }
                }
            }
        }
        w
    }

    /// Total storage in bytes (codes packed, codebooks+scales f16).
    pub fn storage_bytes(&self) -> usize {
        let code_bits = self.n * self.vectors_per_row() * self.cfg.m * self.cfg.b;
        let codebook_bytes = self.cfg.m * self.cfg.n_centroids() * self.cfg.v * 2;
        let scale_bytes = self.n * self.groups_per_row() * 2;
        code_bits.div_ceil(8) + codebook_bytes + scale_bytes
    }

    /// Average bits per weight (matches Eq. 1 of the paper).
    pub fn bits_per_weight(&self) -> f64 {
        footprint::bits_per_weight(&self.cfg, self.n, self.k).total
    }

    /// Internal consistency checks (used by tests and after deserialize).
    pub fn validate(&self) -> Result<()> {
        self.cfg.validate()?;
        if self.k % self.cfg.v != 0 {
            bail!("k ({}) not a multiple of v ({})", self.k, self.cfg.v);
        }
        let expect_cb = self.cfg.m * self.cfg.n_centroids() * self.cfg.v;
        if self.codebooks.len() != expect_cb {
            bail!("codebook len {} != {}", self.codebooks.len(), expect_cb);
        }
        let expect_codes = self.n * self.vectors_per_row() * self.cfg.m;
        if self.codes.len() != expect_codes {
            bail!("codes len {} != {}", self.codes.len(), expect_codes);
        }
        let expect_scales = self.n * self.groups_per_row();
        if self.scales.len() != expect_scales {
            bail!("scales len {} != {}", self.scales.len(), expect_scales);
        }
        if self.codes.max_value() >= self.cfg.n_centroids() {
            bail!("code out of range for b={}", self.cfg.b);
        }
        Ok(())
    }
}

/// High-level quantizer facade with sensible defaults.
#[derive(Clone, Debug)]
pub struct Quantizer {
    pub cfg: QuantConfig,
    /// Max sample vectors used for codebook training (subsampling keeps
    /// k-means tractable on large layers; codes are still assigned to all).
    pub max_train_points: usize,
    /// k-means iterations per codebook.
    pub kmeans_iters: usize,
    /// Alternating refinement rounds (0 = greedy residual only).
    pub refine_rounds: usize,
    pub seed: u64,
}

impl Quantizer {
    pub fn new(cfg: QuantConfig) -> Quantizer {
        Quantizer { cfg, max_train_points: 1 << 16, kmeans_iters: 12, refine_rounds: 1, seed: 0xC0DE }
    }

    pub fn with_refinement(mut self, rounds: usize) -> Quantizer {
        self.refine_rounds = rounds;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Quantizer {
        self.seed = seed;
        self
    }

    /// Quantize a row-major `n×k` weight matrix.
    pub fn quantize(&self, w: &[f32], n: usize, k: usize) -> QuantizedLinear {
        self.quantize_weighted(w, n, k, None)
    }

    /// Quantize with optional per-column importance weights (diag of the
    /// calibration second-moment H — the AQLM/GPTQ-style objective
    /// ‖(W−Ŵ)·diag(h)^{1/2}‖²). `h.len() == k`.
    pub fn quantize_weighted(&self, w: &[f32], n: usize, k: usize, h: Option<&[f32]>) -> QuantizedLinear {
        assert_eq!(w.len(), n * k, "weight length mismatch");
        assert_eq!(k % self.cfg.v, 0, "k must be a multiple of v");
        let aq = AdditiveQuantizer {
            cfg: self.cfg,
            max_train_points: self.max_train_points,
            kmeans_iters: self.kmeans_iters,
            seed: self.seed,
        };
        let refine = RefineOptions { rounds: self.refine_rounds, update_codebooks: true };
        aq.quantize(w, n, k, h, refine)
    }
}

/// Round an entire quantized layer's stored values through the f16 grid
/// (idempotent; exposed for tests).
pub fn f16_sanitize(q: &mut QuantizedLinear) {
    for x in q.codebooks.iter_mut() {
        *x = round_f16(*x);
    }
    for s in q.scales.iter_mut() {
        *s = round_f16(*s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::stats;

    fn random_weight(n: usize, k: usize, seed: u64) -> Vec<f32> {
        Prng::seeded(seed).normal_vec(n * k, 0.02)
    }

    #[test]
    fn quantize_reconstructs_with_bounded_error() {
        let (n, k) = (32, 64);
        let w = random_weight(n, k, 1);
        for label in ["m1v4g-1", "m2v8g32", "m1v8g16"] {
            let cfg = QuantConfig::parse_label(label).unwrap();
            let q = Quantizer::new(cfg).quantize(&w, n, k);
            q.validate().unwrap();
            let wq = q.dequantize();
            let rel = stats::rel_l2(&wq, &w);
            assert!(rel < 0.6, "{label}: rel={rel}");
        }
    }

    #[test]
    fn more_codebooks_reduce_error() {
        let (n, k) = (48, 64);
        let w = random_weight(n, k, 2);
        let err = |m: usize| {
            let cfg = QuantConfig::new(8, m, 6, -1).unwrap();
            let q = Quantizer::new(cfg).quantize(&w, n, k);
            stats::rel_l2(&q.dequantize(), &w)
        };
        let (e1, e2) = (err(1), err(2));
        assert!(e2 < e1, "m=2 ({e2}) should beat m=1 ({e1})");
    }

    #[test]
    fn more_bits_reduce_error() {
        let (n, k) = (48, 64);
        let w = random_weight(n, k, 3);
        let err = |b: usize| {
            let cfg = QuantConfig::new(8, 1, b, -1).unwrap();
            let q = Quantizer::new(cfg).quantize(&w, n, k);
            stats::rel_l2(&q.dequantize(), &w)
        };
        assert!(err(8) < err(4), "8 bits should beat 4 bits");
        assert!(err(4) < err(2), "4 bits should beat 2 bits");
    }

    #[test]
    fn finer_groups_reduce_error_on_heteroscedastic_rows() {
        // Rows whose scale varies along k benefit from finer g.
        let (n, k) = (16, 128);
        let mut rng = Prng::seeded(4);
        let mut w = vec![0f32; n * k];
        for r in 0..n {
            for c in 0..k {
                let band = 1.0 + 9.0 * ((c / 32) as f32 / 3.0); // scale ramps 1x→10x
                w[r * k + c] = rng.normal_f32() * 0.01 * band;
            }
        }
        let err = |g: i64| {
            let cfg = QuantConfig::new(4, 1, 4, g).unwrap();
            let q = Quantizer::new(cfg).quantize(&w, n, k);
            stats::rel_l2(&q.dequantize(), &w)
        };
        assert!(err(32) < err(-1), "g=32 should beat row-wise on banded scales");
    }

    #[test]
    fn storage_matches_eq1_within_rounding() {
        let (n, k) = (64, 256);
        let cfg = QuantConfig::new(8, 2, 8, 128).unwrap();
        let w = random_weight(n, k, 5);
        let q = Quantizer::new(cfg).quantize(&w, n, k);
        let eq1_bits = q.bits_per_weight() * (n * k) as f64;
        let actual_bits = (q.storage_bytes() * 8) as f64;
        let rel = (actual_bits - eq1_bits).abs() / eq1_bits;
        assert!(rel < 0.01, "storage {actual_bits} vs eq1 {eq1_bits}");
    }

    #[test]
    fn validate_catches_corruption() {
        let (n, k) = (8, 16);
        let cfg = QuantConfig::new(4, 1, 4, -1).unwrap();
        let w = random_weight(n, k, 6);
        let mut q = Quantizer::new(cfg).quantize(&w, n, k);
        q.scales.pop();
        assert!(q.validate().is_err());
    }

    #[test]
    fn stored_values_are_f16_exact() {
        let (n, k) = (8, 32);
        let cfg = QuantConfig::new(4, 1, 6, -1).unwrap();
        let w = random_weight(n, k, 7);
        let q = Quantizer::new(cfg).quantize(&w, n, k);
        for &x in q.codebooks.iter().chain(q.scales.iter()) {
            assert_eq!(x, round_f16(x), "stored value {x} not on f16 grid");
        }
    }

    #[test]
    fn refinement_does_not_hurt() {
        let (n, k) = (32, 64);
        let w = random_weight(n, k, 8);
        let cfg = QuantConfig::new(8, 2, 5, -1).unwrap();
        let e0 = {
            let q = Quantizer::new(cfg).with_refinement(0).quantize(&w, n, k);
            stats::rel_l2(&q.dequantize(), &w)
        };
        let e2 = {
            let q = Quantizer::new(cfg).with_refinement(2).quantize(&w, n, k);
            stats::rel_l2(&q.dequantize(), &w)
        };
        assert!(e2 <= e0 * 1.02, "refined {e2} vs greedy {e0}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (n, k) = (16, 32);
        let w = random_weight(n, k, 9);
        let cfg = QuantConfig::new(4, 1, 5, -1).unwrap();
        let q1 = Quantizer::new(cfg).with_seed(11).quantize(&w, n, k);
        let q2 = Quantizer::new(cfg).with_seed(11).quantize(&w, n, k);
        assert_eq!(q1.dequantize(), q2.dequantize());
    }
}
