//! Average bits-per-weight accounting (paper Eq. 1, Table 1).
//!
//! `q̄ = (16·m·2^b·v + b·m·M·K/v + 16·M·K/g) / (M·K)` where the first term
//! is the FP16 codebook, the second the packed codes, the third the FP16
//! group scales (`g = -1` ⇒ one scale per row ⇒ g = K).

use crate::config::QuantConfig;

/// Breakdown of the average bits per weight.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FootprintBreakdown {
    /// Bits/weight spent on codes (paper's q_code).
    pub q_code: f64,
    /// Bits/weight spent on codebooks (q_codebook).
    pub q_codebook: f64,
    /// Bits/weight spent on group scales (q_norm).
    pub q_norm: f64,
    /// Total q̄.
    pub total: f64,
}

/// Compute Eq. 1 for a weight matrix of `n` rows (paper's M) by `k`
/// columns.
pub fn bits_per_weight(cfg: &QuantConfig, n: usize, k: usize) -> FootprintBreakdown {
    let nk = (n * k) as f64;
    let g = cfg.group_size(k) as f64;
    let q_codebook = 16.0 * cfg.m as f64 * cfg.n_centroids() as f64 * cfg.v as f64 / nk;
    let q_code = cfg.b as f64 * cfg.m as f64 * n as f64 * (k as f64 / cfg.v as f64) / nk;
    let q_norm = 16.0 * n as f64 * (k as f64 / g) / nk;
    FootprintBreakdown { q_code, q_codebook, q_norm, total: q_code + q_codebook + q_norm }
}

/// Total quantized bytes for a weight matrix (codes + codebook + scales).
pub fn quantized_bytes(cfg: &QuantConfig, n: usize, k: usize) -> f64 {
    bits_per_weight(cfg, n, k).total * (n * k) as f64 / 8.0
}

/// Bits/weight for uniform quantization with `bits` per weight and group
/// size `g` (FP16 scale per group) — the FlexRound/GPTQ `qX-gY` format.
pub fn uniform_bits_per_weight(bits: usize, g: usize, _n: usize, k: usize) -> f64 {
    let g = g.min(k) as f64;
    bits as f64 + 16.0 / g
}

/// The five configurations of the paper's Table 1, with their published
/// q̄ values, evaluated at Llama-3-8B scale (M=4096, K=4096).
pub fn table1_rows() -> Vec<(QuantConfig, f64)> {
    vec![
        (QuantConfig::new(4, 1, 8, -1).unwrap(), 2.005),
        (QuantConfig::new(8, 2, 8, -1).unwrap(), 2.008),
        (QuantConfig::new(16, 4, 8, -1).unwrap(), 2.020),
        (QuantConfig::new(8, 1, 8, 16).unwrap(), 2.002),
        (QuantConfig::new(16, 3, 8, 32).unwrap(), 2.012),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 4096;
    const K: usize = 4096;

    #[test]
    fn reproduces_table1_exactly() {
        for (cfg, expected) in table1_rows() {
            let got = bits_per_weight(&cfg, N, K).total;
            assert!(
                (got - expected).abs() < 0.002,
                "{}: got {got:.4}, paper says {expected}",
                cfg.label()
            );
        }
    }

    #[test]
    fn table1_component_columns() {
        // Row (v=8, m=1, b=8, g=16): q_code=1.0, q_codebook≈0.002, q_norm=1.0
        let cfg = QuantConfig::new(8, 1, 8, 16).unwrap();
        let f = bits_per_weight(&cfg, N, K);
        assert!((f.q_code - 1.0).abs() < 1e-9);
        assert!((f.q_norm - 1.0).abs() < 1e-9);
        assert!((f.q_codebook - 0.002).abs() < 0.0005);

        // Row (v=16, m=3, b=8, g=32): q_code=1.5, q_norm=0.5
        let cfg = QuantConfig::new(16, 3, 8, 32).unwrap();
        let f = bits_per_weight(&cfg, N, K);
        assert!((f.q_code - 1.5).abs() < 1e-9);
        assert!((f.q_norm - 0.5).abs() < 1e-9);
    }

    #[test]
    fn rowwise_norm_is_16_over_k() {
        let cfg = QuantConfig::new(4, 1, 8, -1).unwrap();
        let f = bits_per_weight(&cfg, N, K);
        assert!((f.q_norm - 16.0 / K as f64).abs() < 1e-12);
    }

    #[test]
    fn headline_configs_match_table4() {
        // Table 4: CodeGEMM-m1v4g128 has q̄ = 2.126 on Llama-3.1-8B.
        // Evaluated on the dominant 4096-wide layers:
        let cfg = QuantConfig::m1v4g128();
        let got = bits_per_weight(&cfg, 4096, 4096).total;
        assert!((got - 2.126).abs() < 0.01, "m1v4g128 q̄ = {got}");
        let cfg = QuantConfig::m2v8g128();
        let got = bits_per_weight(&cfg, 4096, 4096).total;
        assert!((got - 2.127).abs() < 0.01, "m2v8g128 q̄ = {got}");
    }

    #[test]
    fn uniform_q2g128_matches_table4() {
        // FlexRound-q2g128 has q̄ = 2.125 in Table 4.
        let got = uniform_bits_per_weight(2, 128, N, K);
        assert!((got - 2.125).abs() < 1e-9);
    }

    #[test]
    fn codebook_term_scales_with_b() {
        let small = bits_per_weight(&QuantConfig::new(8, 1, 4, -1).unwrap(), N, K).q_codebook;
        let large = bits_per_weight(&QuantConfig::new(8, 1, 8, -1).unwrap(), N, K).q_codebook;
        assert!((large / small - 16.0).abs() < 1e-9); // 2^8/2^4
    }

    #[test]
    fn quantized_bytes_consistent() {
        let cfg = QuantConfig::m1v4g128();
        let b = quantized_bytes(&cfg, N, K);
        let f = bits_per_weight(&cfg, N, K);
        assert!((b * 8.0 - f.total * (N * K) as f64).abs() < 1.0);
    }
}
