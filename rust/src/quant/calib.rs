//! Calibration-aware quantization and post-quantization tuning.
//!
//! Reproduces the paper's accuracy-side toolchain as honest proxies:
//!
//! - **Block-wise codebook optimization (AQLM [5])** → importance-weighted
//!   quantization: the diagonal of the activation second moment
//!   `H = E[x xᵀ]` collected on calibration data weights the k-means /
//!   refinement objective (`‖(W−Ŵ) diag(h)^{1/2}‖²`).
//! - **PV-Tuning [16]** → extended alternating optimization after the
//!   greedy fit: more coordinate-descent + least-squares rounds against
//!   the calibration-weighted objective. (True PV-Tuning backpropagates
//!   through the whole model; the weighted alternating proxy preserves
//!   its *ordering* — "+PV" rows improve over base — which is what the
//!   paper's tables exercise.) See DESIGN.md §Substitutions.

use crate::config::QuantConfig;
use crate::quant::{AdditiveQuantizer, QuantizedLinear, RefineOptions};

/// Diagonal of the calibration second moment `E[x xᵀ]` for one linear
/// layer, estimated from sample activations.
#[derive(Clone, Debug)]
pub struct CalibStats {
    pub k: usize,
    pub n_samples: usize,
    /// Running sum of x².
    sum_sq: Vec<f64>,
}

impl CalibStats {
    pub fn new(k: usize) -> CalibStats {
        CalibStats { k, n_samples: 0, sum_sq: vec![0.0; k] }
    }

    /// Accumulate one activation vector.
    pub fn observe(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.k);
        self.n_samples += 1;
        for (s, &v) in self.sum_sq.iter_mut().zip(x) {
            *s += (v as f64) * (v as f64);
        }
    }

    /// Accumulate a batch of row-major activations `(rows × k)`.
    pub fn observe_batch(&mut self, xs: &[f32]) {
        assert_eq!(xs.len() % self.k, 0);
        for row in xs.chunks_exact(self.k) {
            self.observe(row);
        }
    }

    /// Per-column importance h = E[x²] (+ epsilon damping, like GPTQ's
    /// percdamp, so dead columns keep nonzero weight).
    pub fn importance(&self) -> Vec<f32> {
        if self.n_samples == 0 {
            return vec![1.0; self.k];
        }
        let mean: Vec<f64> = self.sum_sq.iter().map(|s| s / self.n_samples as f64).collect();
        let avg = mean.iter().sum::<f64>() / self.k as f64;
        let damp = 0.01 * avg + 1e-12;
        mean.iter().map(|&m| (m + damp) as f32).collect()
    }
}

/// Tuning intensity presets matching the paper's table rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneLevel {
    /// Greedy residual quantization only (no calibration).
    None,
    /// Calibration-weighted objective, light refinement (AQLM-class).
    Calibrated,
    /// Calibration-weighted + extended alternating rounds ("+PV-Tuning").
    PvTuned,
}

impl TuneLevel {
    pub fn refine_rounds(self) -> usize {
        match self {
            TuneLevel::None => 0,
            TuneLevel::Calibrated => 1,
            TuneLevel::PvTuned => 4,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            TuneLevel::None => "base",
            TuneLevel::Calibrated => "calib",
            TuneLevel::PvTuned => "+PV-Tuning",
        }
    }
}

/// Quantize one layer at the given tuning level.
pub fn quantize_with_level(
    cfg: QuantConfig,
    w: &[f32],
    n: usize,
    k: usize,
    calib: Option<&CalibStats>,
    level: TuneLevel,
    seed: u64,
) -> QuantizedLinear {
    let aq = AdditiveQuantizer { cfg, max_train_points: 1 << 16, kmeans_iters: 12, seed };
    let h = match level {
        TuneLevel::None => None,
        _ => calib.map(|c| c.importance()),
    };
    let refine = RefineOptions { rounds: level.refine_rounds(), update_codebooks: true };
    aq.quantize(w, n, k, h.as_deref(), refine)
}

/// Weighted reconstruction error `‖(W−Ŵ) diag(h)^{1/2}‖²/‖W diag(h)^{1/2}‖²`
/// — the objective the calibration stage optimizes; used by tests and the
/// ablation bench.
pub fn weighted_rel_error(w: &[f32], wq: &[f32], n: usize, k: usize, h: &[f32]) -> f64 {
    assert_eq!(w.len(), n * k);
    assert_eq!(wq.len(), n * k);
    assert_eq!(h.len(), k);
    let mut num = 0f64;
    let mut den = 0f64;
    for r in 0..n {
        for c in 0..k {
            let d = (wq[r * k + c] - w[r * k + c]) as f64;
            let x = w[r * k + c] as f64;
            num += d * d * h[c] as f64;
            den += x * x * h[c] as f64;
        }
    }
    if den == 0.0 {
        0.0
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn calib_with_hot_columns(k: usize, hot: std::ops::Range<usize>) -> CalibStats {
        let mut rng = Prng::seeded(10);
        let mut stats = CalibStats::new(k);
        for _ in 0..64 {
            let mut x: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            for c in hot.clone() {
                x[c] *= 10.0;
            }
            stats.observe(&x);
        }
        stats
    }

    #[test]
    fn importance_reflects_activation_energy() {
        let stats = calib_with_hot_columns(32, 0..4);
        let h = stats.importance();
        let hot_mean: f32 = h[..4].iter().sum::<f32>() / 4.0;
        let cold_mean: f32 = h[4..].iter().sum::<f32>() / 28.0;
        assert!(hot_mean > 20.0 * cold_mean, "hot {hot_mean} vs cold {cold_mean}");
    }

    #[test]
    fn empty_calib_gives_uniform_importance() {
        let stats = CalibStats::new(8);
        assert_eq!(stats.importance(), vec![1.0; 8]);
    }

    #[test]
    fn pv_tuning_improves_weighted_objective() {
        let (n, k) = (32, 32);
        let w = Prng::seeded(11).normal_vec(n * k, 0.02);
        let stats = calib_with_hot_columns(k, 0..8);
        let h = stats.importance();
        let cfg = QuantConfig::new(4, 1, 3, -1).unwrap();
        let base = quantize_with_level(cfg, &w, n, k, Some(&stats), TuneLevel::None, 1);
        let tuned = quantize_with_level(cfg, &w, n, k, Some(&stats), TuneLevel::PvTuned, 1);
        let e_base = weighted_rel_error(&w, &base.dequantize(), n, k, &h);
        let e_tuned = weighted_rel_error(&w, &tuned.dequantize(), n, k, &h);
        assert!(e_tuned <= e_base * 1.001, "tuned {e_tuned} vs base {e_base}");
    }

    #[test]
    fn observe_batch_equivalent_to_loop() {
        let k = 8;
        let mut rng = Prng::seeded(12);
        let xs = rng.normal_vec(4 * k, 1.0);
        let mut a = CalibStats::new(k);
        a.observe_batch(&xs);
        let mut b = CalibStats::new(k);
        for row in xs.chunks_exact(k) {
            b.observe(row);
        }
        assert_eq!(a.importance(), b.importance());
        assert_eq!(a.n_samples, 4);
    }

    #[test]
    fn tune_levels_ordered() {
        assert_eq!(TuneLevel::None.refine_rounds(), 0);
        assert!(TuneLevel::PvTuned.refine_rounds() > TuneLevel::Calibrated.refine_rounds());
    }

    #[test]
    fn weighted_error_zero_for_exact() {
        let w = vec![1.0f32, 2.0, 3.0, 4.0];
        let h = vec![1.0f32, 1.0];
        assert_eq!(weighted_rel_error(&w, &w, 2, 2, &h), 0.0);
    }
}
