//! Additive multi-codebook quantization (AQLM-style, paper §2.2).
//!
//! Greedy residual stage: codebook 0 is k-means over the normalized weight
//! vectors; codebook `c` is k-means over the residual left by codebooks
//! `0..c`. Optional alternating refinement (the PV-Tuning-class
//! post-optimization): coordinate descent over codes per codebook followed
//! by least-squares centroid updates, which strictly decreases the
//! (importance-weighted) reconstruction error.

use crate::config::QuantConfig;
use crate::quant::kmeans::{assign, kmeans, KMeansOptions};
use crate::quant::normalize::GroupScales;
use crate::quant::pack::PackedCodes;
use crate::quant::QuantizedLinear;
use crate::util::f16::round_f16_slice;
use crate::util::prng::Prng;

/// Refinement options for the alternating stage.
#[derive(Clone, Copy, Debug)]
pub struct RefineOptions {
    /// Number of full alternating rounds (0 disables refinement).
    pub rounds: usize,
    /// Whether centroids are re-fit after code reassignment (the
    /// "PV-Tuning" half); codes-only refinement keeps codebooks frozen.
    pub update_codebooks: bool,
}

impl Default for RefineOptions {
    fn default() -> Self {
        RefineOptions { rounds: 1, update_codebooks: true }
    }
}

/// The additive quantizer. See [`crate::quant::Quantizer`] for the facade.
#[derive(Clone, Debug)]
pub struct AdditiveQuantizer {
    pub cfg: QuantConfig,
    pub max_train_points: usize,
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl AdditiveQuantizer {
    /// Quantize normalized + grouped weights into codebooks/codes/scales.
    /// `h` is an optional per-column importance vector of length `k`
    /// (diagonal of the calibration second moment).
    pub fn quantize(
        &self,
        w: &[f32],
        n: usize,
        k: usize,
        h: Option<&[f32]>,
        refine: RefineOptions,
    ) -> QuantizedLinear {
        let cfg = self.cfg;
        let v = cfg.v;
        let jn = k / v;
        let n_points = n * jn;
        let mut rng = Prng::seeded(self.seed);

        // Step 1: group normalization.
        let (scales, normalized) = GroupScales::compute(w, n, k, &cfg);

        // Vectors tile rows contiguously, so `normalized` doubles as the
        // flat point array (point p = (r, j) at offset p * v).
        let points: &[f32] = &normalized;

        // Per-point importance: mean of h over the vector's column span.
        let point_weights: Option<Vec<f32>> = h.map(|h| {
            assert_eq!(h.len(), k, "importance vector must have length k");
            let per_j: Vec<f32> = (0..jn)
                .map(|j| {
                    let s: f32 = h[j * v..(j + 1) * v].iter().sum();
                    (s / v as f32).max(1e-12)
                })
                .collect();
            (0..n_points).map(|p| per_j[p % jn]).collect()
        });

        // Step 2/3: residual k-means per codebook.
        let mut residual: Vec<f32> = points.to_vec();
        let mut codebooks: Vec<f32> = Vec::with_capacity(cfg.m * cfg.n_centroids() * v);
        let mut codes: Vec<u32> = vec![0; n_points * cfg.m]; // [p][c]
        for c in 0..cfg.m {
            let (train_pts, train_w) = self.subsample(&residual, point_weights.as_deref(), v, &mut rng);
            let mut res = kmeans(
                &train_pts,
                train_w.as_deref(),
                KMeansOptions {
                    n_clusters: cfg.n_centroids(),
                    dim: v,
                    max_iters: self.kmeans_iters,
                    seed: rng.next_u64(),
                    tol: 1e-4,
                },
            );
            // Codebook values are stored in FP16 on device.
            round_f16_slice(&mut res.centroids);
            // Assign *all* points against the trained codebook.
            let (asg, _) = assign(&residual, &res.centroids, v, None);
            for p in 0..n_points {
                codes[p * cfg.m + c] = asg[p];
                let cent = &res.centroids[asg[p] as usize * v..(asg[p] as usize + 1) * v];
                for t in 0..v {
                    residual[p * v + t] -= cent[t];
                }
            }
            codebooks.extend_from_slice(&res.centroids);
        }

        // Step 4: alternating refinement.
        for _ in 0..refine.rounds {
            self.refine_round(points, point_weights.as_deref(), &mut codebooks, &mut codes, n_points, refine);
        }

        let packed = PackedCodes::pack(&codes, cfg.b).expect("codes fit in b bits");
        QuantizedLinear { cfg, n, k, codebooks, codes: packed, scales: scales.scales }
    }

    /// Subsample points (and weights) for codebook training.
    fn subsample(
        &self,
        points: &[f32],
        weights: Option<&[f32]>,
        dim: usize,
        rng: &mut Prng,
    ) -> (Vec<f32>, Option<Vec<f32>>) {
        let n = points.len() / dim;
        if n <= self.max_train_points {
            return (points.to_vec(), weights.map(|w| w.to_vec()));
        }
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        idx.truncate(self.max_train_points);
        let mut pts = Vec::with_capacity(self.max_train_points * dim);
        let mut ws = weights.map(|_| Vec::with_capacity(self.max_train_points));
        for &p in &idx {
            pts.extend_from_slice(&points[p * dim..(p + 1) * dim]);
            if let (Some(ws), Some(w)) = (ws.as_mut(), weights) {
                ws.push(w[p]);
            }
        }
        (pts, ws)
    }

    /// One alternating round: per codebook, coordinate-descent code
    /// reassignment against the residual target, then (optionally)
    /// weighted least-squares centroid re-fit.
    fn refine_round(
        &self,
        points: &[f32],
        weights: Option<&[f32]>,
        codebooks: &mut [f32],
        codes: &mut [u32],
        n_points: usize,
        opts: RefineOptions,
    ) {
        let cfg = self.cfg;
        let v = cfg.v;
        let nc = cfg.n_centroids();
        // Current reconstruction per point.
        let mut recon = vec![0f32; n_points * v];
        for p in 0..n_points {
            for c in 0..cfg.m {
                let code = codes[p * cfg.m + c] as usize;
                let cent = &codebooks[(c * nc + code) * v..(c * nc + code + 1) * v];
                for t in 0..v {
                    recon[p * v + t] += cent[t];
                }
            }
        }
        let mut target = vec![0f32; v];
        for c in 0..cfg.m {
            let cb = c * nc * v;
            // (a) reassign codes for codebook c.
            for p in 0..n_points {
                let old = codes[p * cfg.m + c] as usize;
                let old_cent: Vec<f32> = codebooks[cb + old * v..cb + (old + 1) * v].to_vec();
                for t in 0..v {
                    target[t] = points[p * v + t] - (recon[p * v + t] - old_cent[t]);
                }
                let mut best = old;
                let mut best_d = f32::INFINITY;
                for i in 0..nc {
                    let cent = &codebooks[cb + i * v..cb + (i + 1) * v];
                    let mut d = 0f32;
                    for t in 0..v {
                        let e = target[t] - cent[t];
                        d += e * e;
                    }
                    if d < best_d {
                        best_d = d;
                        best = i;
                    }
                }
                if best != old {
                    codes[p * cfg.m + c] = best as u32;
                    let new_cent = &codebooks[cb + best * v..cb + (best + 1) * v];
                    for t in 0..v {
                        recon[p * v + t] += new_cent[t] - old_cent[t];
                    }
                }
            }
            // (b) least-squares centroid update for codebook c.
            if opts.update_codebooks {
                let mut sums = vec![0f64; nc * v];
                let mut wsum = vec![0f64; nc];
                for p in 0..n_points {
                    let code = codes[p * cfg.m + c] as usize;
                    let wgt = weights.map(|w| w[p] as f64).unwrap_or(1.0);
                    wsum[code] += wgt;
                    let cent = &codebooks[cb + code * v..cb + (code + 1) * v];
                    for t in 0..v {
                        // target for this point under fixed other codes:
                        let tgt = points[p * v + t] - (recon[p * v + t] - cent[t]);
                        sums[code * v + t] += tgt as f64 * wgt;
                    }
                }
                for i in 0..nc {
                    if wsum[i] > 0.0 {
                        let old: Vec<f32> = codebooks[cb + i * v..cb + (i + 1) * v].to_vec();
                        for t in 0..v {
                            codebooks[cb + i * v + t] = (sums[i * v + t] / wsum[i]) as f32;
                        }
                        round_f16_slice(&mut codebooks[cb + i * v..cb + (i + 1) * v]);
                        // Patch reconstructions for members of centroid i.
                        let newc: Vec<f32> = codebooks[cb + i * v..cb + (i + 1) * v].to_vec();
                        for p in 0..n_points {
                            if codes[p * cfg.m + c] as usize == i {
                                for t in 0..v {
                                    recon[p * v + t] += newc[t] - old[t];
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn quantizer(cfg: QuantConfig) -> AdditiveQuantizer {
        AdditiveQuantizer { cfg, max_train_points: 1 << 14, kmeans_iters: 10, seed: 7 }
    }

    #[test]
    fn exact_recovery_when_data_is_clusterable() {
        // Weights drawn from exactly 4 distinct vectors; b=2 (4 centroids)
        // must reconstruct (nearly) exactly.
        let v = 4;
        // All prototypes share absmax = 1 so row-wise normalization maps
        // every row onto the same 4 points (exactly clusterable).
        let protos: [[f32; 4]; 4] = [
            [1.0, -0.5, 0.25, 0.0],
            [-0.25, 1.0, -0.5, 0.5],
            [0.0, 0.0, 1.0, -1.0],
            [1.0, 0.125, -0.75, 0.25],
        ];
        let (n, k) = (16, 32);
        let mut rng = Prng::seeded(1);
        let mut w = vec![0f32; n * k];
        for p in 0..(n * k / v) {
            let proto = protos[rng.index(4)];
            w[p * v..(p + 1) * v].copy_from_slice(&proto);
        }
        let cfg = QuantConfig::new(4, 1, 2, -1).unwrap();
        let q = quantizer(cfg).quantize(&w, n, k, None, RefineOptions { rounds: 1, update_codebooks: true });
        let rel = stats::rel_l2(&q.dequantize(), &w);
        assert!(rel < 0.02, "clusterable data should reconstruct, rel={rel}");
    }

    #[test]
    fn refinement_monotonically_improves_weighted_objective() {
        let (n, k) = (24, 64);
        let w = Prng::seeded(2).normal_vec(n * k, 0.02);
        let cfg = QuantConfig::new(8, 2, 4, -1).unwrap();
        let aq = quantizer(cfg);
        let mut prev = f64::INFINITY;
        for rounds in [0usize, 1, 3] {
            let q = aq.quantize(&w, n, k, None, RefineOptions { rounds, update_codebooks: true });
            let err = stats::mse(&q.dequantize(), &w);
            assert!(err <= prev * 1.01, "rounds={rounds}: {err} > prev {prev}");
            prev = err;
        }
    }

    #[test]
    fn importance_weights_prioritize_heavy_columns() {
        // Columns 0..v get 100x importance; the weighted quantizer should
        // achieve lower error there than the unweighted one.
        let (n, k) = (32, 32);
        let v = 4;
        let w = Prng::seeded(3).normal_vec(n * k, 0.02);
        let mut h = vec![1f32; k];
        for t in 0..v {
            h[t] = 100.0;
        }
        let cfg = QuantConfig::new(4, 1, 3, -1).unwrap();
        let aq = quantizer(cfg);
        let err_on_heavy = |q: &QuantizedLinear| {
            let wq = q.dequantize();
            let mut e = 0f64;
            for r in 0..n {
                for t in 0..v {
                    e += ((wq[r * k + t] - w[r * k + t]) as f64).powi(2);
                }
            }
            e
        };
        let q_plain = aq.quantize(&w, n, k, None, RefineOptions { rounds: 2, update_codebooks: true });
        let q_weighted = aq.quantize(&w, n, k, Some(&h), RefineOptions { rounds: 2, update_codebooks: true });
        assert!(
            err_on_heavy(&q_weighted) <= err_on_heavy(&q_plain) * 1.05,
            "weighted {} vs plain {}",
            err_on_heavy(&q_weighted),
            err_on_heavy(&q_plain)
        );
    }

    #[test]
    fn codes_within_range_all_configs() {
        let (n, k) = (8, 32);
        let w = Prng::seeded(4).normal_vec(n * k, 1.0);
        for (v, m, b) in [(4, 1, 2), (8, 3, 3), (16, 2, 5)] {
            let cfg = QuantConfig::new(v, m, b, -1).unwrap();
            let q = quantizer(cfg).quantize(&w, n, k, None, RefineOptions::default());
            assert!(q.codes.max_value() < cfg.n_centroids());
            q.validate().unwrap();
        }
    }

    #[test]
    fn subsampling_still_produces_valid_quantization() {
        let (n, k) = (64, 64);
        let w = Prng::seeded(5).normal_vec(n * k, 0.02);
        let cfg = QuantConfig::new(4, 1, 6, -1).unwrap();
        let mut aq = quantizer(cfg);
        aq.max_train_points = 64; // force heavy subsampling (1024 points)
        let q = aq.quantize(&w, n, k, None, RefineOptions::default());
        q.validate().unwrap();
        let rel = stats::rel_l2(&q.dequantize(), &w);
        assert!(rel < 0.7, "rel={rel}");
    }
}
