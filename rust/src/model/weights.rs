//! Model weight container: load/save via the `TensorFile` interchange
//! format shared with `python/compile/export.py`, random initialization
//! for tests, and an analytically-constructed bigram model whose
//! perplexity on the synthetic corpus is provably below uniform — used
//! by accuracy-trend tests when no trained artifact is available.

use crate::config::ModelConfig;
use crate::util::npy::{Tensor, TensorFile};
use crate::util::prng::Prng;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One decoder layer's dense weights (row-major `n × k`, `y = W x`).
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub wq: Vec<f32>,     // hidden × hidden
    pub wk: Vec<f32>,     // kv_dim × hidden
    pub wv: Vec<f32>,     // kv_dim × hidden
    pub wo: Vec<f32>,     // hidden × hidden
    pub w_gate: Vec<f32>, // ffn × hidden
    pub w_up: Vec<f32>,   // ffn × hidden
    pub w_down: Vec<f32>, // hidden × ffn
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
}

/// Full model weights.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub cfg: ModelConfig,
    /// Token embedding, `vocab × hidden` row-major.
    pub embedding: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    /// LM head, `vocab × hidden`.
    pub lm_head: Vec<f32>,
}

/// The seven linear-layer names of a decoder block, in kernel order.
pub const LINEAR_NAMES: [&str; 7] = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

impl ModelWeights {
    /// Random small-scale initialization (for mechanics tests).
    pub fn random(cfg: ModelConfig, seed: u64) -> ModelWeights {
        let mut rng = Prng::seeded(seed);
        let d = cfg.hidden;
        let kv = cfg.kv_dim();
        let std = 1.0 / (d as f32).sqrt();
        let mk = |rng: &mut Prng, n: usize| rng.normal_vec(n, std);
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                wq: mk(&mut rng, d * d),
                wk: mk(&mut rng, kv * d),
                wv: mk(&mut rng, kv * d),
                wo: mk(&mut rng, d * d),
                w_gate: mk(&mut rng, cfg.ffn * d),
                w_up: mk(&mut rng, cfg.ffn * d),
                w_down: mk(&mut rng, d * cfg.ffn),
                attn_norm: vec![1.0; d],
                mlp_norm: vec![1.0; d],
            })
            .collect();
        ModelWeights {
            embedding: mk(&mut rng, cfg.vocab * d),
            layers,
            final_norm: vec![1.0; d],
            lm_head: mk(&mut rng, cfg.vocab * d),
            cfg,
        }
    }

    /// Construct a model that computes (approximately) a *bigram* language
    /// model for the given `vocab × vocab` transition log-probabilities:
    /// the embedding encodes the current token, the transformer layers are
    /// near-identity (tiny weights pass the residual through), and
    /// `lm_head · embedding ≈ log P(next | cur)`.
    ///
    /// Used by accuracy-trend tests: quantizing these weights degrades the
    /// bigram fit in exactly the way the paper's Figure 4(b) sweeps over.
    pub fn bigram(cfg: ModelConfig, log_probs: &[f32], seed: u64) -> ModelWeights {
        // The corpus may use a sub-vocabulary (cv ≤ cfg.vocab); with
        // cv ≤ hidden the token codes can be exactly orthogonal, making
        // the construction lossless up to the damped-layer residue.
        let cv = (log_probs.len() as f64).sqrt().round() as usize;
        assert_eq!(log_probs.len(), cv * cv);
        assert!(cv <= cfg.vocab, "corpus vocab {cv} exceeds model vocab {}", cfg.vocab);
        let mut w = ModelWeights::random(cfg.clone(), seed);
        let d = cfg.hidden;
        // Dampen attention/MLP so the residual dominates.
        for l in &mut w.layers {
            for x in l
                .wo
                .iter_mut()
                .chain(l.w_down.iter_mut())
                .chain(l.wv.iter_mut())
            {
                *x *= 0.002;
            }
        }
        // Random dense code for each token (near-orthogonal for d >= 64),
        // then lm_head rows chosen so lm_head · rmsnorm(e(tok)) ≈ the
        // *baseline-shifted* log-probs: per-row we encode only the sparse
        // successor mass lp − min_row(lp) (softmax is shift-invariant), so
        // the ~vocab-wide smoothing floor does not pollute the projection
        // with cross-talk.
        let mut rng = Prng::seeded(seed ^ 0xB16A);
        let scale = 1.0 / (d as f32).sqrt();
        for x in w.embedding.iter_mut() {
            *x = rng.normal_f32() * scale;
        }
        if cv <= d && d.is_power_of_two() {
            // Exactly orthogonal *dense* codes (rows of the Sylvester
            // Hadamard matrix): zero cross-talk between tokens, and the
            // resulting lm_head is dense so quantization error actually
            // spreads across it (one-hot codes would leave it sparse and
            // trivially quantizable).
            for cur in 0..cv {
                let row = &mut w.embedding[cur * d..(cur + 1) * d];
                for (j, x) in row.iter_mut().enumerate() {
                    let sign = if (cur & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
                    *x = sign * scale;
                }
            }
        }
        let mut lm = vec![0f32; cfg.vocab * d];
        for cur in 0..cv {
            let row = &log_probs[cur * cv..(cur + 1) * cv];
            let base = row.iter().cloned().fold(f32::MAX, f32::min);
            let e = w.embedding[cur * d..(cur + 1) * d].to_vec();
            let norm2: f32 = e.iter().map(|x| x * x).sum();
            // The final RMSNorm rescales h ≈ e(cur) to e / rms(e); encode
            // against that normalized code so the logits land on scale.
            let rms = (norm2 / d as f32).sqrt();
            for (next, &lp) in row.iter().enumerate() {
                let shifted = lp - base;
                if shifted <= 1e-4 {
                    continue;
                }
                for t in 0..d {
                    lm[next * d + t] += shifted * e[t] * rms / norm2;
                }
            }
        }
        w.lm_head = lm;
        w
    }

    /// All linear layers as `(name, n, k, data)` tuples (the quantization
    /// targets; embeddings and norms stay fp16/fp32 as in the paper).
    pub fn linears(&self) -> Vec<(String, usize, usize, &[f32])> {
        let d = self.cfg.hidden;
        let kv = self.cfg.kv_dim();
        let ffn = self.cfg.ffn;
        let mut out = Vec::new();
        for (i, l) in self.layers.iter().enumerate() {
            let dims: [(&str, usize, usize, &[f32]); 7] = [
                ("wq", d, d, &l.wq),
                ("wk", kv, d, &l.wk),
                ("wv", kv, d, &l.wv),
                ("wo", d, d, &l.wo),
                ("w_gate", ffn, d, &l.w_gate),
                ("w_up", ffn, d, &l.w_up),
                ("w_down", d, ffn, &l.w_down),
            ];
            for (name, n, k, data) in dims {
                out.push((format!("layers.{i}.{name}"), n, k, data));
            }
        }
        out.push(("lm_head".into(), self.cfg.vocab, d, self.lm_head.as_slice()));
        out
    }

    /// Serialize to the shared TensorFile container.
    pub fn to_tensor_file(&self) -> TensorFile {
        let cfg = &self.cfg;
        let d = cfg.hidden;
        let mut tf = TensorFile::new();
        tf.push(Tensor::f32("embedding", vec![cfg.vocab, d], self.embedding.clone()));
        for (i, l) in self.layers.iter().enumerate() {
            let p = |s: &str| format!("layers.{i}.{s}");
            tf.push(Tensor::f32(&p("wq"), vec![d, d], l.wq.clone()));
            tf.push(Tensor::f32(&p("wk"), vec![cfg.kv_dim(), d], l.wk.clone()));
            tf.push(Tensor::f32(&p("wv"), vec![cfg.kv_dim(), d], l.wv.clone()));
            tf.push(Tensor::f32(&p("wo"), vec![d, d], l.wo.clone()));
            tf.push(Tensor::f32(&p("w_gate"), vec![cfg.ffn, d], l.w_gate.clone()));
            tf.push(Tensor::f32(&p("w_up"), vec![cfg.ffn, d], l.w_up.clone()));
            tf.push(Tensor::f32(&p("w_down"), vec![d, cfg.ffn], l.w_down.clone()));
            tf.push(Tensor::f32(&p("attn_norm"), vec![d], l.attn_norm.clone()));
            tf.push(Tensor::f32(&p("mlp_norm"), vec![d], l.mlp_norm.clone()));
        }
        tf.push(Tensor::f32("final_norm", vec![d], self.final_norm.clone()));
        tf.push(Tensor::f32("lm_head", vec![cfg.vocab, d], self.lm_head.clone()));
        tf
    }

    /// Load from a TensorFile written by rust or `python/compile/export.py`.
    pub fn from_tensor_file(cfg: ModelConfig, tf: &TensorFile) -> Result<ModelWeights> {
        cfg.validate()?;
        let d = cfg.hidden;
        let getf = |name: &str, want: usize| -> Result<Vec<f32>> {
            let t = tf.get(name)?;
            let data = t.data.as_f32().with_context(|| format!("{name} must be f32"))?;
            if data.len() != want {
                bail!("{name}: expected {want} elements, got {}", data.len());
            }
            Ok(data.to_vec())
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |s: &str| format!("layers.{i}.{s}");
            layers.push(LayerWeights {
                wq: getf(&p("wq"), d * d)?,
                wk: getf(&p("wk"), cfg.kv_dim() * d)?,
                wv: getf(&p("wv"), cfg.kv_dim() * d)?,
                wo: getf(&p("wo"), d * d)?,
                w_gate: getf(&p("w_gate"), cfg.ffn * d)?,
                w_up: getf(&p("w_up"), cfg.ffn * d)?,
                w_down: getf(&p("w_down"), d * cfg.ffn)?,
                attn_norm: getf(&p("attn_norm"), d)?,
                mlp_norm: getf(&p("mlp_norm"), d)?,
            });
        }
        Ok(ModelWeights {
            embedding: getf("embedding", cfg.vocab * d)?,
            layers,
            final_norm: getf("final_norm", d)?,
            lm_head: getf("lm_head", cfg.vocab * d)?,
            cfg,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        self.to_tensor_file().save(path)
    }

    pub fn load(cfg: ModelConfig, path: impl AsRef<Path>) -> Result<ModelWeights> {
        let tf = TensorFile::load(path)?;
        ModelWeights::from_tensor_file(cfg, &tf)
    }

    /// Total parameter count of the stored tensors.
    pub fn n_params(&self) -> usize {
        self.to_tensor_file().tensors.iter().map(|t| t.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_roundtrips_through_tensor_file() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(cfg.clone(), 3);
        let tf = w.to_tensor_file();
        let w2 = ModelWeights::from_tensor_file(cfg, &tf).unwrap();
        assert_eq!(w.embedding, w2.embedding);
        assert_eq!(w.layers[1].w_down, w2.layers[1].w_down);
        assert_eq!(w.lm_head, w2.lm_head);
    }

    #[test]
    fn linears_cover_block_and_head() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(cfg.clone(), 3);
        let lin = w.linears();
        assert_eq!(lin.len(), cfg.n_layers * 7 + 1);
        let (_, n, k, data) = &lin[0];
        assert_eq!((*n, *k), (cfg.hidden, cfg.hidden));
        assert_eq!(data.len(), n * k);
    }

    #[test]
    fn param_count_matches_config() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(cfg.clone(), 3);
        // to_tensor_file stores every parameter exactly once.
        assert_eq!(w.n_params(), cfg.n_params());
    }

    #[test]
    fn missing_tensor_is_an_error() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::random(cfg.clone(), 3);
        let mut tf = w.to_tensor_file();
        tf.tensors.retain(|t| t.name != "lm_head");
        assert!(ModelWeights::from_tensor_file(cfg, &tf).is_err());
    }
}
