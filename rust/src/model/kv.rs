//! Per-sequence **contiguous** key/value cache for autoregressive
//! decoding: one `max_seq`-sized allocation per layer, made up front.
//!
//! This is the simple representation used by direct model runs (eval,
//! benches, examples). The serving backend uses the paged pool instead
//! ([`crate::kvcache`]), which bounds memory by pool pages rather than
//! `slots × max_seq`. Both implement [`crate::kvcache::KvStore`] — the
//! contiguous cache reads back as a single whole-cache tile — so every
//! model forward path works identically over either.

use crate::kvcache::KvStore;

/// KV cache for one sequence across all layers.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub n_layers: usize,
    pub max_seq: usize,
    pub kv_dim: usize,
    /// `k[layer][pos * kv_dim + t]`
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Number of positions filled so far.
    pub len: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, max_seq: usize, kv_dim: usize) -> KvCache {
        KvCache {
            n_layers,
            max_seq,
            kv_dim,
            k: vec![vec![0.0; max_seq * kv_dim]; n_layers],
            v: vec![vec![0.0; max_seq * kv_dim]; n_layers],
            len: 0,
        }
    }

    /// Bytes held by this cache (capacity: the full `max_seq` allocation,
    /// regardless of fill — see [`Self::bytes_used`] for the fill).
    pub fn bytes(&self) -> usize {
        2 * self.n_layers * self.max_seq * self.kv_dim * 4
    }

    /// Bytes actually filled (`len` positions across all layers).
    pub fn bytes_used(&self) -> usize {
        2 * self.n_layers * self.len * self.kv_dim * 4
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.max_seq
    }

    /// Write k/v for `layer` at position `pos` (must be `<= len`; writing
    /// at `len` on the last layer advances the cache).
    pub fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.kv_dim);
        debug_assert_eq!(v.len(), self.kv_dim);
        assert!(pos < self.max_seq, "kv cache overflow: pos {pos} >= {}", self.max_seq);
        let off = pos * self.kv_dim;
        self.k[layer][off..off + self.kv_dim].copy_from_slice(k);
        self.v[layer][off..off + self.kv_dim].copy_from_slice(v);
        if layer + 1 == self.n_layers && pos >= self.len {
            self.len = pos + 1;
        }
    }

    /// Cached keys for `layer`, positions `0..=pos`.
    #[inline]
    pub fn keys(&self, layer: usize, upto: usize) -> &[f32] {
        &self.k[layer][..upto * self.kv_dim]
    }

    #[inline]
    pub fn values(&self, layer: usize, upto: usize) -> &[f32] {
        &self.v[layer][..upto * self.kv_dim]
    }

    /// Drop all cached state (reuse the allocation).
    pub fn clear(&mut self) {
        self.len = 0;
    }
}

/// The contiguous cache as a tile source: one whole-cache tile, so the
/// chunked attention kernel degenerates to the flat loop it replaced
/// (bit-exact by construction).
impl KvStore for KvCache {
    fn len(&self) -> usize {
        self.len
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn kv_dim(&self) -> usize {
        self.kv_dim
    }

    fn n_layers(&self) -> usize {
        self.n_layers
    }

    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        KvCache::write(self, layer, pos, k, v)
    }

    fn clear(&mut self) {
        KvCache::clear(self)
    }

    fn tile_tokens(&self) -> usize {
        self.max_seq
    }

    fn k_tile<'a>(&'a self, layer: usize, t: usize, upto: usize, _buf: &'a mut Vec<f32>) -> &'a [f32] {
        debug_assert_eq!(t, 0, "contiguous cache has a single tile");
        self.keys(layer, upto)
    }

    fn v_tile<'a>(&'a self, layer: usize, t: usize, upto: usize, _buf: &'a mut Vec<f32>) -> &'a [f32] {
        debug_assert_eq!(t, 0, "contiguous cache has a single tile");
        self.values(layer, upto)
    }

    fn bytes(&self) -> usize {
        KvCache::bytes(self)
    }

    fn bytes_used(&self) -> usize {
        KvCache::bytes_used(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_and_read_roundtrip() {
        let mut c = KvCache::new(2, 8, 4);
        let k = [1.0, 2.0, 3.0, 4.0];
        let v = [5.0, 6.0, 7.0, 8.0];
        c.write(0, 0, &k, &v);
        c.write(1, 0, &k, &v);
        assert_eq!(c.len, 1);
        assert_eq!(c.keys(0, 1), &k);
        assert_eq!(c.values(1, 1), &v);
    }

    #[test]
    fn len_advances_only_on_last_layer() {
        let mut c = KvCache::new(3, 8, 2);
        c.write(0, 0, &[0.0; 2], &[0.0; 2]);
        assert_eq!(c.len, 0);
        c.write(2, 0, &[0.0; 2], &[0.0; 2]);
        assert_eq!(c.len, 1);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 2, 2);
        c.write(0, 2, &[0.0; 2], &[0.0; 2]);
    }

    #[test]
    fn clear_resets_len() {
        let mut c = KvCache::new(1, 4, 2);
        c.write(0, 0, &[1.0; 2], &[1.0; 2]);
        c.clear();
        assert_eq!(c.len, 0);
        assert!(!c.is_full());
    }

    #[test]
    fn bytes_reports_capacity_and_fill_separately() {
        let mut c = KvCache::new(2, 8, 4);
        assert_eq!(c.bytes(), 2 * 2 * 8 * 4 * 4);
        assert_eq!(c.bytes_used(), 0);
        c.write(0, 0, &[0.0; 4], &[0.0; 4]);
        c.write(1, 0, &[0.0; 4], &[0.0; 4]);
        assert_eq!(c.bytes_used(), 2 * 2 * 1 * 4 * 4);
        assert!(c.bytes_used() <= c.bytes());
    }

    #[test]
    fn contiguous_cache_is_a_single_tile() {
        let mut c = KvCache::new(1, 8, 2);
        let k = [1.0, 2.0];
        let v = [3.0, 4.0];
        c.write(0, 0, &k, &v);
        assert_eq!(KvStore::tile_tokens(&c), 8);
        assert_eq!(KvStore::n_tiles(&c, 1), 1);
        let mut buf = Vec::new();
        assert_eq!(KvStore::k_tile(&c, 0, 0, 1, &mut buf), &k);
        let mut buf = Vec::new();
        assert_eq!(KvStore::v_tile(&c, 0, 0, 1, &mut buf), &v);
        assert!(buf.is_empty(), "f32 contiguous reads are zero-copy");
    }
}
