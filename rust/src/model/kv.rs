//! Per-sequence key/value cache for autoregressive decoding.

/// KV cache for one sequence across all layers.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub n_layers: usize,
    pub max_seq: usize,
    pub kv_dim: usize,
    /// `k[layer][pos * kv_dim + t]`
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Number of positions filled so far.
    pub len: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, max_seq: usize, kv_dim: usize) -> KvCache {
        KvCache {
            n_layers,
            max_seq,
            kv_dim,
            k: vec![vec![0.0; max_seq * kv_dim]; n_layers],
            v: vec![vec![0.0; max_seq * kv_dim]; n_layers],
            len: 0,
        }
    }

    /// Bytes held by this cache (capacity, not fill).
    pub fn bytes(&self) -> usize {
        2 * self.n_layers * self.max_seq * self.kv_dim * 4
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.max_seq
    }

    /// Write k/v for `layer` at position `pos` (must be `<= len`; writing
    /// at `len` on the last layer advances the cache).
    pub fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.kv_dim);
        debug_assert_eq!(v.len(), self.kv_dim);
        assert!(pos < self.max_seq, "kv cache overflow: pos {pos} >= {}", self.max_seq);
        let off = pos * self.kv_dim;
        self.k[layer][off..off + self.kv_dim].copy_from_slice(k);
        self.v[layer][off..off + self.kv_dim].copy_from_slice(v);
        if layer + 1 == self.n_layers && pos >= self.len {
            self.len = pos + 1;
        }
    }

    /// Cached keys for `layer`, positions `0..=pos`.
    #[inline]
    pub fn keys(&self, layer: usize, upto: usize) -> &[f32] {
        &self.k[layer][..upto * self.kv_dim]
    }

    #[inline]
    pub fn values(&self, layer: usize, upto: usize) -> &[f32] {
        &self.v[layer][..upto * self.kv_dim]
    }

    /// Drop all cached state (reuse the allocation).
    pub fn clear(&mut self) {
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_and_read_roundtrip() {
        let mut c = KvCache::new(2, 8, 4);
        let k = [1.0, 2.0, 3.0, 4.0];
        let v = [5.0, 6.0, 7.0, 8.0];
        c.write(0, 0, &k, &v);
        c.write(1, 0, &k, &v);
        assert_eq!(c.len, 1);
        assert_eq!(c.keys(0, 1), &k);
        assert_eq!(c.values(1, 1), &v);
    }

    #[test]
    fn len_advances_only_on_last_layer() {
        let mut c = KvCache::new(3, 8, 2);
        c.write(0, 0, &[0.0; 2], &[0.0; 2]);
        assert_eq!(c.len, 0);
        c.write(2, 0, &[0.0; 2], &[0.0; 2]);
        assert_eq!(c.len, 1);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 2, 2);
        c.write(0, 2, &[0.0; 2], &[0.0; 2]);
    }

    #[test]
    fn clear_resets_len() {
        let mut c = KvCache::new(1, 4, 2);
        c.write(0, 0, &[1.0; 2], &[1.0; 2]);
        c.clear();
        assert_eq!(c.len, 0);
        assert!(!c.is_full());
    }
}
