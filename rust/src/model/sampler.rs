//! Token sampling for the decode loop.

use crate::util::prng::Prng;
use crate::util::stats::softmax_inplace;

/// Greedy / temperature sampler.
#[derive(Clone, Debug)]
pub struct Sampler {
    pub temperature: f32,
    rng: Prng,
}

impl Sampler {
    pub fn new(temperature: f32, seed: u64) -> Sampler {
        Sampler { temperature, rng: Prng::seeded(seed) }
    }

    pub fn greedy() -> Sampler {
        Sampler::new(0.0, 0)
    }

    /// Pick the next token from raw logits.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        if self.temperature <= 0.0 {
            return argmax(logits);
        }
        let mut probs: Vec<f32> = logits.iter().map(|&x| x / self.temperature).collect();
        softmax_inplace(&mut probs);
        let r = self.rng.uniform_f32();
        let mut acc = 0f32;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if r < acc {
                return i;
            }
        }
        probs.len() - 1
    }
}

/// Index of the maximum logit (ties → lowest index).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::greedy();
        assert_eq!(s.sample(&[0.1, 3.0, 2.0]), 1);
    }

    #[test]
    fn argmax_tie_breaks_low() {
        assert_eq!(argmax(&[1.0, 1.0, 0.0]), 0);
    }

    #[test]
    fn temperature_sampling_spreads_mass() {
        let mut s = Sampler::new(1.0, 7);
        let logits = vec![1.0f32, 1.0, 1.0, 1.0];
        let mut seen = [0usize; 4];
        for _ in 0..200 {
            seen[s.sample(&logits)] += 1;
        }
        assert!(seen.iter().all(|&c| c > 10), "uniform logits should hit all tokens: {seen:?}");
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut s = Sampler::new(0.05, 7);
        let logits = vec![0.0f32, 5.0, 0.0];
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
    }
}
