//! Pure-Rust Llama-style decoder over pluggable GEMM engines.
//!
//! This is the accuracy-evaluation substrate: the same trained weights are
//! loaded under fp32 / CodeGEMM / dequant / uniform / LUT engines and the
//! resulting models are compared on perplexity and task accuracy
//! (`crate::eval`), reproducing the paper's Tables 4/5 and Figure 4(b)
//! trends on the tiny model.

pub mod engine_factory;
pub mod kv;
pub mod llama;
pub mod sampler;
pub mod weights;

pub use engine_factory::EngineKind;
pub use kv::KvCache;
pub use llama::{rmsnorm, silu, LlamaModel, MAX_PREFILL_CHUNK};
pub use sampler::{argmax, Sampler};
pub use weights::{LayerWeights, ModelWeights};
