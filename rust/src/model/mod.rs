//! Pure-Rust Llama-style decoder over pluggable GEMM engines.
//!
//! This is the accuracy-evaluation substrate: the same trained weights are
//! loaded under fp32 / CodeGEMM / dequant / uniform / LUT engines and the
//! resulting models are compared on perplexity and task accuracy
//! (`crate::eval`), reproducing the paper's Tables 4/5 and Figure 4(b)
//! trends on the tiny model.
//!
//! ## KV cache and attention
//!
//! Every forward path ([`LlamaModel::forward_into`] decode,
//! [`LlamaModel::forward_batch`] batched prefill) is generic over
//! [`crate::kvcache::KvStore`], so the same code runs against two cache
//! representations:
//!
//! - [`KvCache`] (this module) — one contiguous `max_seq` allocation per
//!   sequence, used by direct model runs (eval, benches, examples);
//! - `kvcache::PagedKv` — page-table views into the shared
//!   `kvcache::BlockPool` arena, used by the serving backend so pool
//!   pages (not `slots × max_seq`) bound KV memory.
//!
//! Attention is a real kernel now, not an inline loop:
//! [`attention::attend`] is a chunked two-pass GQA kernel that walks the
//! cache tile-by-tile (tile height = pool page size, tiles outer so each
//! page-table resolution serves every head) and is **bit-exact** against
//! the flat loop for any tile size — so paging is purely a memory layout
//! decision, never a numerics one. The page size is thereby an attention
//! tiling knob to tune like the GEMM `tile_w`/`tile_h`. Prefill chunks
//! route through [`attention::attend_batch`], which walks each K/V tile
//! once per *chunk* (tile × queries score blocks, causal mask inside the
//! tile loop) — bit-exact vs the per-position walk, and the piece that
//! makes coded KV dtypes (`KvConfig::kv_dtype` = f32/f16/int8) cheap:
//! each page decodes once per chunk into [`attention::AttnScratch`],
//! not once per position.
//!
//! ## Fused projection groups
//!
//! The linears sharing one input activation — Q/K/V over the attn-normed
//! hidden state, gate/up over the MLP-normed one — load as
//! [`ProjectionSet`]s ([`EngineKind::build_projection_set`]): under
//! CodeGEMM the members are quantized jointly (stacked rows, shared
//! codebooks) and execute as one `gemm::GemmGroup` call that builds each
//! k-tile's Psumbook once for all members —
//! `ParallelConfig::fused_projections` toggles the schedule with
//! bit-identical outputs.

pub mod attention;
pub mod engine_factory;
pub mod kv;
pub mod llama;
pub mod sampler;
pub mod weights;

pub use attention::{attend, attend_batch, AttnScratch, AttnShape};
pub use engine_factory::{EngineKind, ProjectionSet};
pub use kv::KvCache;
pub use llama::{rmsnorm, silu, LlamaModel, MAX_PREFILL_CHUNK};
pub use sampler::{argmax, Sampler};
pub use weights::{LayerWeights, ModelWeights};
