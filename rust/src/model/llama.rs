//! Pure-Rust Llama-style decoder running every linear layer through a
//! pluggable [`GemmEngine`] — the accuracy-evaluation substrate for
//! Tables 4/5 and Figure 4(b): the *same* model weights are loaded under
//! fp32, CodeGEMM, dequant, uniform or LUT engines and compared.
//!
//! Architecture (matches `python/compile/model.py` exactly): token
//! embedding → N × [RMSNorm → GQA attention with RoPE → residual →
//! RMSNorm → SwiGLU MLP → residual] → RMSNorm → LM head.
//!
//! Execution model: every linear runs through the zero-allocation
//! `gemm_into` core. The model owns one [`ForwardScratch`] holding every
//! activation buffer plus a single shared [`EngineScratch`], reused
//! across layers, steps and requests — after the first token the decode
//! hot loop performs no heap allocation ([`LlamaModel::forward_into`]),
//! and prefill runs as true batched GEMMs over the whole prompt
//! ([`LlamaModel::forward_batch`]) so the Psumbook build cost amortizes
//! across the batch dimension exactly as the paper's Eq. 3 predicts.
//!
//! Every forward path is generic over [`KvStore`]: the same code decodes
//! against the contiguous per-sequence [`KvCache`] and against the paged
//! pool (`kvcache::PagedKv`) — in any KV dtype (f32/f16/int8 coded
//! pages). Attention itself lives in [`super::attention`]: decode (`m =
//! 1`) runs the chunked per-position kernel, prefill chunks (`m > 1`)
//! run the batched score-block kernel that walks each K/V tile once per
//! chunk — bit-exact against the per-position walk it replaced.

use super::attention::{attend, attend_batch, AttnScratch, AttnShape};
use super::engine_factory::{EngineKind, ProjectionSet};
use super::kv::KvCache;
use super::weights::ModelWeights;
use crate::config::{ModelConfig, ParallelConfig};
use crate::gemm::scratch::grow_slice;
use crate::gemm::{Counters, EngineScratch, GemmEngine};
use crate::kvcache::KvStore;
use crate::parallel::ShardPlan;
use crate::util::threadpool::ThreadPool;
use crate::util::timer::PhaseTimer;
use std::sync::Arc;
use std::time::Instant;

/// Engines cap `m_batch` at 64 (the Psumbook batch axis); longer prompts
/// prefill in chunks of this size.
pub const MAX_PREFILL_CHUNK: usize = 64;

/// Engines for one decoder layer. The projections sharing one input
/// activation — Q/K/V over the attn-normed hidden state, gate/up over
/// the MLP-normed one — are [`ProjectionSet`]s: under CodeGEMM they fuse
/// around one shared Psumbook build per k-tile (`gemm::GemmGroup`),
/// which is where the decode-time build work per layer drops ~3× for
/// attention and ~2× for the MLP. O and down consume *different*
/// activations and stay standalone engines.
struct LayerEngines {
    qkv: ProjectionSet,
    wo: Box<dyn GemmEngine + Send + Sync>,
    gate_up: ProjectionSet,
    w_down: Box<dyn GemmEngine + Send + Sync>,
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
}

/// Reusable activation buffers for the forward pass — grown once to the
/// largest shape seen (layer width × batch chunk), then reused across
/// layers, steps and requests. Engines draw their own tile/table scratch
/// from the single shared [`EngineScratch`].
#[derive(Default)]
struct ForwardScratch {
    h: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    act: Vec<f32>,
    scores: Vec<f32>,
    /// Attention tile decode buffers + resolution counter — coded KV
    /// pools decode each walked tile into here.
    attn: AttnScratch,
    eng: EngineScratch,
    /// Cumulative per-phase wall time of every forward through this
    /// scratch: `model/gemm` (all linears), `model/attention`
    /// (RoPE + KV write + attention kernel), `model/lm_head`. Riding in
    /// the scratch keeps `step_batch` on `&self` and the accounting on
    /// the same take/put path as the activation buffers.
    timer: PhaseTimer,
}

/// A Llama model whose linears run through a chosen kernel engine.
pub struct LlamaModel {
    pub cfg: ModelConfig,
    pub kind_label: String,
    embedding: Vec<f32>,
    layers: Vec<LayerEngines>,
    final_norm: Vec<f32>,
    lm_head: Box<dyn GemmEngine + Send + Sync>,
    /// Precomputed RoPE tables: `cos/sin[pos * half + i]`.
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    scratch: ForwardScratch,
}

/// Precompute RoPE tables (`cos/sin[pos * half + i]`).
fn rope_tables(cfg: &ModelConfig) -> (Vec<f32>, Vec<f32>) {
    let hd = cfg.head_dim();
    let half = hd / 2;
    let mut rope_cos = vec![0f32; cfg.max_seq * half];
    let mut rope_sin = vec![0f32; cfg.max_seq * half];
    for pos in 0..cfg.max_seq {
        for i in 0..half {
            let freq = 1.0 / cfg.rope_theta().powf(2.0 * i as f32 / hd as f32);
            let angle = pos as f32 * freq;
            rope_cos[pos * half + i] = angle.cos();
            rope_sin[pos * half + i] = angle.sin();
        }
    }
    (rope_cos, rope_sin)
}

/// RMS normalization: `y = x * w / rms(x)`.
pub fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// SiLU activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Apply RoPE to `x` (heads of `head_dim`, rotate-half convention matching
/// `python/compile/model.py`).
pub fn rope_rotate(x: &mut [f32], head_dim: usize, cos: &[f32], sin: &[f32]) {
    let half = head_dim / 2;
    for head in x.chunks_mut(head_dim) {
        for i in 0..half {
            let (a, b) = (head[i], head[half + i]);
            head[i] = a * cos[i] - b * sin[i];
            head[half + i] = b * cos[i] + a * sin[i];
        }
    }
}

impl LlamaModel {
    /// Quantize (if applicable) and load `weights` under engine `kind`.
    /// `calib` optionally provides per-linear column importances keyed by
    /// the same order as `ModelWeights::linears()`. Projections sharing
    /// an input activation (Q/K/V, gate/up) load as fused sets.
    pub fn load(weights: &ModelWeights, kind: EngineKind, calib: Option<&[Vec<f32>]>) -> LlamaModel {
        Self::load_with_options(weights, kind, calib, true)
    }

    /// [`Self::load`] with the fused-projection schedule explicit.
    /// Quantization is identical either way (the stacked joint
    /// quantization happens regardless), so a model loaded with
    /// `fused_projections` off is **bit-exact** vs. one loaded with it
    /// on — only the Psumbook build count per layer differs.
    pub fn load_with_options(
        weights: &ModelWeights,
        kind: EngineKind,
        calib: Option<&[Vec<f32>]>,
        fused_projections: bool,
    ) -> LlamaModel {
        let cfg = weights.cfg.clone();
        let d = cfg.hidden;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        let mut li = 0usize;
        let h = |i: &mut usize| -> Option<&[f32]> {
            let r = calib.map(|c| c[*i].as_slice());
            *i += 1;
            r
        };
        for l in &weights.layers {
            let kv = cfg.kv_dim();
            // Calibration order matches `ModelWeights::linears()`:
            // wq, wk, wv, wo, w_gate, w_up, w_down.
            let h_qkv = [h(&mut li), h(&mut li), h(&mut li)];
            let qkv = kind.build_projection_set(
                &[(l.wq.as_slice(), d), (l.wk.as_slice(), kv), (l.wv.as_slice(), kv)],
                d,
                &h_qkv,
                fused_projections,
                None,
            );
            let wo = kind.build(&l.wo, d, d, h(&mut li));
            let h_mlp = [h(&mut li), h(&mut li)];
            let gate_up = kind.build_projection_set(
                &[(l.w_gate.as_slice(), cfg.ffn), (l.w_up.as_slice(), cfg.ffn)],
                d,
                &h_mlp,
                fused_projections,
                None,
            );
            let w_down = kind.build(&l.w_down, d, cfg.ffn, h(&mut li));
            layers.push(LayerEngines {
                qkv,
                wo,
                gate_up,
                w_down,
                attn_norm: l.attn_norm.clone(),
                mlp_norm: l.mlp_norm.clone(),
            });
        }
        let lm_head = kind.build(&weights.lm_head, cfg.vocab, d, h(&mut li));
        let (rope_cos, rope_sin) = rope_tables(&cfg);
        LlamaModel {
            kind_label: kind.label(),
            embedding: weights.embedding.clone(),
            layers,
            final_norm: weights.final_norm.clone(),
            lm_head,
            rope_cos,
            rope_sin,
            scratch: ForwardScratch::default(),
            cfg,
        }
    }

    /// Tensor-parallel load: every linear is sharded across `pool`
    /// according to `par`, per layer class:
    ///
    /// - Q/K/V, gate/up and the LM head are **column-parallel** (output
    ///   rows sharded; on the decode path each worker writes its
    ///   sub-slice of the caller's output buffer — bit-exact vs. serial);
    /// - O and down are **row-parallel** (reduction dim sharded,
    ///   partials combined by the deterministic ordered all-reduce —
    ///   deterministic, equal to serial up to float reassociation).
    ///
    /// Every worker gets its own per-shard `EngineScratch` (Psumbook/LUT
    /// scratch), mirroring the per-thread-block tables of the GPU
    /// kernels.
    pub fn load_parallel(
        weights: &ModelWeights,
        kind: EngineKind,
        calib: Option<&[Vec<f32>]>,
        par: &ParallelConfig,
        pool: Arc<ThreadPool>,
    ) -> LlamaModel {
        let cfg = weights.cfg.clone();
        let d = cfg.hidden;
        let threads = par.effective_threads();
        let min = par.shard_min_rows;
        // Column-parallel (output-dim) builder for one linear. Row-shard
        // boundaries align to the engine's row-block height so shard
        // blocking stays congruent with the serial engine's k-tile walk.
        let col = |w: &[f32], n: usize, k: usize, h: Option<&[f32]>, on: bool| {
            if on {
                let plan = ShardPlan::tiled(n, threads, min, kind.row_shard_align());
                kind.build_sharded(w, n, k, h, &plan, Arc::clone(&pool), par.shared_psumbook)
            } else {
                kind.build(w, n, k, h)
            }
        };
        // Row-parallel (reduction-dim) builder for one linear.
        let row = |w: &[f32], n: usize, k: usize, h: Option<&[f32]>, on: bool| {
            if on {
                let plan = ShardPlan::new(k, threads, min, kind.k_shard_align(k));
                kind.build_row_sharded(w, n, k, h, &plan, Arc::clone(&pool))
            } else {
                kind.build(w, n, k, h)
            }
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        let mut li = 0usize;
        let h = |i: &mut usize| -> Option<&[f32]> {
            let r = calib.map(|c| c[*i].as_slice());
            *i += 1;
            r
        };
        for l in &weights.layers {
            let kv = cfg.kv_dim();
            // Q/K/V and gate/up load as projection sets: column-parallel
            // row shards per member when the layer class shards, fused
            // around one shared Psumbook build when the kind supports it
            // (the book is then shared across shards *and* members).
            let h_qkv = [h(&mut li), h(&mut li), h(&mut li)];
            let qkv = kind.build_projection_set(
                &[(l.wq.as_slice(), d), (l.wk.as_slice(), kv), (l.wv.as_slice(), kv)],
                d,
                &h_qkv,
                par.fused_projections_effective(),
                if par.shard_attn { Some((par, &pool)) } else { None },
            );
            let wo = row(&l.wo, d, d, h(&mut li), par.shard_attn);
            let h_mlp = [h(&mut li), h(&mut li)];
            let gate_up = kind.build_projection_set(
                &[(l.w_gate.as_slice(), cfg.ffn), (l.w_up.as_slice(), cfg.ffn)],
                d,
                &h_mlp,
                par.fused_projections_effective(),
                if par.shard_mlp { Some((par, &pool)) } else { None },
            );
            let w_down = row(&l.w_down, d, cfg.ffn, h(&mut li), par.shard_mlp);
            layers.push(LayerEngines {
                qkv,
                wo,
                gate_up,
                w_down,
                attn_norm: l.attn_norm.clone(),
                mlp_norm: l.mlp_norm.clone(),
            });
        }
        let lm_head = col(&weights.lm_head, cfg.vocab, d, h(&mut li), par.shard_lm_head);
        let (rope_cos, rope_sin) = rope_tables(&cfg);
        LlamaModel {
            kind_label: format!("{}+shard{}", kind.label(), threads),
            embedding: weights.embedding.clone(),
            layers,
            final_norm: weights.final_norm.clone(),
            lm_head,
            rope_cos,
            rope_sin,
            scratch: ForwardScratch::default(),
            cfg,
        }
    }

    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.cfg.n_layers, self.cfg.max_seq, self.cfg.kv_dim())
    }

    /// One decode step: token at position `pos` → logits over the vocab,
    /// written into the caller-owned `logits` (`vocab` long). Appends
    /// this position's K/V to `cache` (contiguous or paged). This is the
    /// zero-allocation hot loop: every activation and engine buffer comes
    /// from the model's reused scratch.
    pub fn forward_into<C: KvStore>(
        &mut self,
        token: usize,
        pos: usize,
        cache: &mut C,
        logits: &mut [f32],
    ) {
        let mut s = std::mem::take(&mut self.scratch);
        self.step_batch(&[token], pos, cache, Some(logits), &mut s);
        self.scratch = s;
    }

    /// One decode step: token at position `pos` → logits over the vocab
    /// (allocating wrapper over [`Self::forward_into`]).
    pub fn forward<C: KvStore>(&mut self, token: usize, pos: usize, cache: &mut C) -> Vec<f32> {
        let mut logits = vec![0f32; self.cfg.vocab];
        self.forward_into(token, pos, cache, &mut logits);
        logits
    }

    /// Batched prefill: run `tokens` (occupying positions
    /// `pos0 .. pos0 + tokens.len()`) through every layer as true
    /// `m_batch = tokens.len()` GEMMs — the regime where the Psumbook
    /// build cost `O(m·2^b·K·N_blocks·M)` amortizes over the gather
    /// (paper Eq. 3) — with causal attention batched per chunk through
    /// `attend_batch` (each K/V tile walked once per chunk). Returns the
    /// logits after the final token.
    ///
    /// Matches token-by-token [`Self::forward`] up to float
    /// reassociation inside the engines' batched kernels (bit-exact for
    /// the dense engine, ≤1e-5 rel-L2 for the table kernels).
    pub fn forward_batch<C: KvStore>(
        &mut self,
        tokens: &[usize],
        pos0: usize,
        cache: &mut C,
    ) -> Vec<f32> {
        self.forward_batch_logits(tokens, pos0, cache, true)
            .expect("logits requested")
    }

    /// [`Self::forward_batch`] with the LM head optional: when
    /// `want_logits` is false the final chunk also skips the lm_head GEMM
    /// (the largest single GEMM in the model) and `None` is returned —
    /// the right call for prefill chunks that are *not* the end of the
    /// prompt, whose logits the scheduler would discard.
    pub fn forward_batch_logits<C: KvStore>(
        &mut self,
        tokens: &[usize],
        pos0: usize,
        cache: &mut C,
        want_logits: bool,
    ) -> Option<Vec<f32>> {
        assert!(!tokens.is_empty(), "forward_batch needs at least one token");
        let mut logits = if want_logits { vec![0f32; self.cfg.vocab] } else { Vec::new() };
        let mut s = std::mem::take(&mut self.scratch);
        let mut pos = pos0;
        let n_chunks = tokens.len().div_ceil(MAX_PREFILL_CHUNK);
        for (ci, chunk) in tokens.chunks(MAX_PREFILL_CHUNK).enumerate() {
            // The LM head only matters for the final position — skip it
            // on non-final chunks (and entirely when unwanted).
            let want = want_logits && ci + 1 == n_chunks;
            let out = if want { Some(logits.as_mut_slice()) } else { None };
            self.step_batch(chunk, pos, cache, out, &mut s);
            pos += chunk.len();
        }
        self.scratch = s;
        if want_logits {
            Some(logits)
        } else {
            None
        }
    }

    /// Run a whole prompt (from position 0), returning logits after the
    /// final token.
    pub fn prefill<C: KvStore>(&mut self, tokens: &[usize], cache: &mut C) -> Vec<f32> {
        self.forward_batch(tokens, 0, cache)
    }

    /// The shared forward core: one batch chunk of `m = tokens.len()`
    /// positions through every layer (`m == 1` is the decode step).
    /// When `logits` is `Some`, runs the LM head on the final position
    /// and writes its logits; `None` skips the LM head entirely
    /// (non-final prefill chunks only need the KV cache side effects).
    fn step_batch<C: KvStore>(
        &self,
        tokens: &[usize],
        pos0: usize,
        cache: &mut C,
        logits: Option<&mut [f32]>,
        s: &mut ForwardScratch,
    ) {
        let cfg = &self.cfg;
        let m = tokens.len();
        debug_assert!(m >= 1 && m <= MAX_PREFILL_CHUNK);
        let d = cfg.hidden;
        let hd = cfg.head_dim();
        let kv_dim = cfg.kv_dim();
        let shape = AttnShape::of(cfg);
        let half = hd / 2;

        let h = grow_slice(&mut s.h, m * d);
        for (b, &t) in tokens.iter().enumerate() {
            assert!(t < cfg.vocab, "token {t} out of vocab");
            h[b * d..(b + 1) * d].copy_from_slice(&self.embedding[t * d..(t + 1) * d]);
        }
        let normed = grow_slice(&mut s.normed, m * d);
        let q = grow_slice(&mut s.q, m * d);
        let kk = grow_slice(&mut s.k, m * kv_dim);
        let vv = grow_slice(&mut s.v, m * kv_dim);
        let attn_out = grow_slice(&mut s.attn_out, m * d);
        let proj = grow_slice(&mut s.proj, m * d);
        let gate = grow_slice(&mut s.gate, m * cfg.ffn);
        let up = grow_slice(&mut s.up, m * cfg.ffn);
        let act = grow_slice(&mut s.act, m * cfg.ffn);
        // Sized to the full context for this chunk width up front (one
        // `max_seq`-long row per query per head) so the buffer never
        // grows mid-sequence (pos0 + m <= max_seq, enforced by the
        // cache); decode (m = 1) needs exactly the old n_heads × max_seq.
        let scores = grow_slice(&mut s.scores, shape.scores_len_batch(m, cfg.max_seq));
        let attn = &mut s.attn;
        let eng = &mut s.eng;
        let timer = &mut s.timer;
        let scale = 1.0 / (hd as f32).sqrt();

        for (layer_i, l) in self.layers.iter().enumerate() {
            // ---- attention ----
            for b in 0..m {
                rmsnorm(&h[b * d..(b + 1) * d], &l.attn_norm, &mut normed[b * d..(b + 1) * d]);
            }
            // One grouped call: under a fused CodeGEMM set the Psumbook
            // for each k-tile is built once and gathered by Q, K and V.
            let tg = Instant::now();
            l.qkv.gemm_set_into(normed, m, &mut [&mut *q, &mut *kk, &mut *vv], eng);
            timer.add("model/gemm", tg.elapsed().as_secs_f64());
            let ta = Instant::now();
            for b in 0..m {
                let pos = pos0 + b;
                let cos = &self.rope_cos[pos * half..(pos + 1) * half];
                let sin = &self.rope_sin[pos * half..(pos + 1) * half];
                rope_rotate(&mut q[b * d..(b + 1) * d], hd, cos, sin);
                rope_rotate(&mut kk[b * kv_dim..(b + 1) * kv_dim], hd, cos, sin);
                cache.write(
                    layer_i,
                    pos,
                    &kk[b * kv_dim..(b + 1) * kv_dim],
                    &vv[b * kv_dim..(b + 1) * kv_dim],
                );
            }
            // Causal attention: position `pos0 + b` attends to
            // `0..=pos0+b`, all already written above. Prefill chunks
            // (m > 1) take the batched score-block kernel — each K/V
            // tile is resolved (and, for coded pools, decoded) once per
            // chunk instead of once per position; decode keeps the
            // per-position kernel. Both walk the cache tile-by-tile and
            // agree bitwise (see `super::attention`).
            if m == 1 {
                attend(
                    &*cache,
                    layer_i,
                    &shape,
                    &q[..d],
                    pos0 + 1,
                    scale,
                    attn,
                    scores,
                    &mut attn_out[..d],
                );
            } else {
                attend_batch(
                    &*cache, layer_i, &shape, q, pos0, m, scale, attn, scores, attn_out,
                );
            }
            timer.add("model/attention", ta.elapsed().as_secs_f64());
            let tg = Instant::now();
            l.wo.gemm_into(attn_out, m, proj, eng);
            timer.add("model/gemm", tg.elapsed().as_secs_f64());
            for i in 0..m * d {
                h[i] += proj[i];
            }
            // ---- MLP ----
            for b in 0..m {
                rmsnorm(&h[b * d..(b + 1) * d], &l.mlp_norm, &mut normed[b * d..(b + 1) * d]);
            }
            let tg = Instant::now();
            l.gate_up.gemm_set_into(normed, m, &mut [&mut *gate, &mut *up], eng);
            timer.add("model/gemm", tg.elapsed().as_secs_f64());
            for i in 0..m * cfg.ffn {
                act[i] = silu(gate[i]) * up[i];
            }
            let tg = Instant::now();
            l.w_down.gemm_into(act, m, proj, eng);
            timer.add("model/gemm", tg.elapsed().as_secs_f64());
            for i in 0..m * d {
                h[i] += proj[i];
            }
        }
        // LM head on the final position only (and only when requested).
        if let Some(logits) = logits {
            assert_eq!(logits.len(), cfg.vocab);
            let normed_last = &mut normed[..d];
            rmsnorm(&h[(m - 1) * d..m * d], &self.final_norm, normed_last);
            let tl = Instant::now();
            self.lm_head.gemm_into(normed_last, 1, logits, eng);
            timer.add("model/lm_head", tl.elapsed().as_secs_f64());
        }
    }

    /// Sum of work/traffic counters across the model: the shared forward
    /// scratch (where `forward`/`forward_batch` accumulate) merged with
    /// every engine's built-in counters (legacy direct-call paths;
    /// projection sets route everything through the shared scratch).
    pub fn total_counters(&self) -> Counters {
        let mut total = self.scratch.eng.counters.clone();
        for l in &self.layers {
            l.qkv.merge_counters(&mut total);
            l.gate_up.merge_counters(&mut total);
            for e in [&l.wo, &l.w_down] {
                total.merge(e.counters());
            }
        }
        total.merge(self.lm_head.counters());
        total
    }

    /// Cumulative per-phase forward wall time (`model/gemm`,
    /// `model/attention`, `model/lm_head`) accumulated by every forward
    /// through this model's scratch — the step-phase breakdown the
    /// serving metrics surface next to the engine's build/gather split.
    pub fn phases(&self) -> &PhaseTimer {
        &self.scratch.timer
    }

    /// High-water footprint of the shared engine scratch, split by
    /// buffer (`buf`, `buf2`, `book`, `book2` bytes) — the working set
    /// `obs::roofline::FootprintAudit` places against the cache
    /// hierarchy. Reflects the largest tile geometry any layer has run.
    pub fn scratch_parts(&self) -> (usize, usize, usize, usize) {
        self.scratch.eng.footprint_parts()
    }

    /// True when every layer's Q/K/V and gate/up sets take the fused
    /// one-shared-build schedule (introspection for tests and labels).
    pub fn uses_fused_projections(&self) -> bool {
        self.layers.iter().all(|l| l.qkv.is_fused() && l.gate_up.is_fused())
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, QuantConfig};
    use crate::util::stats;

    fn tiny() -> ModelWeights {
        ModelWeights::random(ModelConfig::tiny(), 42)
    }

    #[test]
    fn forward_produces_finite_logits() {
        let w = tiny();
        let mut m = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut cache = m.new_cache();
        let logits = m.forward(65, 0, &mut cache);
        assert_eq!(logits.len(), w.cfg.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(cache.len, 1);
    }

    #[test]
    fn deterministic_across_runs() {
        let w = tiny();
        let run = || {
            let mut m = LlamaModel::load(&w, EngineKind::Dense, None);
            let mut c = m.new_cache();
            m.prefill(&[10, 20, 30], &mut c)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn kv_cache_consistent_with_recompute() {
        // Decoding [a, b, c] step-by-step must equal prefilling [a, b, c].
        let w = tiny();
        let mut m1 = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut c1 = m1.new_cache();
        let l1 = m1.prefill(&[7, 8, 9], &mut c1);
        let mut m2 = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut c2 = m2.new_cache();
        m2.forward(7, 0, &mut c2);
        m2.forward(8, 1, &mut c2);
        let l2 = m2.forward(9, 2, &mut c2);
        assert!(stats::rel_l2(&l1, &l2) < 1e-6);
    }

    #[test]
    fn forward_batch_matches_sequential_forward() {
        // The batched prefill must reproduce token-by-token decoding: the
        // dense engine's batched path is per-column identical, so logits
        // agree to float exactness; the KV caches must agree too.
        let w = tiny();
        let prompt = [5usize, 99, 7, 3, 250, 1];
        let mut mb = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut cb = mb.new_cache();
        let lb = mb.forward_batch(&prompt, 0, &mut cb);
        let mut ms = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut cs = ms.new_cache();
        let mut ls = Vec::new();
        for (pos, &t) in prompt.iter().enumerate() {
            ls = ms.forward(t, pos, &mut cs);
        }
        let rel = stats::rel_l2(&lb, &ls);
        assert!(rel < 1e-6, "batched prefill diverged: rel {rel}");
        assert_eq!(cb.len, cs.len);
        // Decoding after either prefill gives the same continuation.
        let a = mb.forward(42, prompt.len(), &mut cb);
        let b = ms.forward(42, prompt.len(), &mut cs);
        assert!(stats::rel_l2(&a, &b) < 1e-6);
    }

    #[test]
    fn forward_batch_matches_sequential_forward_quantized() {
        // Table kernels reassociate the batched gather: equal within the
        // acceptance tolerance, not bitwise.
        let w = tiny();
        let cfg = QuantConfig::new(4, 1, 6, 32).unwrap();
        let prompt = [11usize, 23, 5, 8];
        let mut mb = LlamaModel::load(&w, EngineKind::codegemm(cfg), None);
        let mut cb = mb.new_cache();
        let lb = mb.forward_batch(&prompt, 0, &mut cb);
        let mut ms = LlamaModel::load(&w, EngineKind::codegemm(cfg), None);
        let mut cs = ms.new_cache();
        let mut ls = Vec::new();
        for (pos, &t) in prompt.iter().enumerate() {
            ls = ms.forward(t, pos, &mut cs);
        }
        let rel = stats::rel_l2(&lb, &ls);
        assert!(rel < 1e-5, "batched quantized prefill diverged: rel {rel}");
    }

    #[test]
    fn forward_batch_chunks_long_prompts() {
        // A prompt longer than MAX_PREFILL_CHUNK must prefill correctly
        // across chunk boundaries.
        let w = tiny();
        let prompt: Vec<usize> = (0..MAX_PREFILL_CHUNK + 5).map(|i| (i * 7) % 250 + 1).collect();
        let mut mb = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut cb = mb.new_cache();
        let lb = mb.forward_batch(&prompt, 0, &mut cb);
        let mut ms = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut cs = ms.new_cache();
        let mut ls = Vec::new();
        for (pos, &t) in prompt.iter().enumerate() {
            ls = ms.forward(t, pos, &mut cs);
        }
        assert!(stats::rel_l2(&lb, &ls) < 1e-6);
        assert_eq!(cb.len, prompt.len());
    }

    #[test]
    fn attention_attends_to_history() {
        // Changing an *earlier* token must change later logits (the cache
        // is actually read).
        let w = tiny();
        let mut m = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut ca = m.new_cache();
        let la = m.prefill(&[1, 2, 3], &mut ca);
        let mut cb = m.new_cache();
        let lb = m.prefill(&[200, 2, 3], &mut cb);
        assert!(stats::rel_l2(&la, &lb) > 1e-4, "history must influence logits");
    }

    #[test]
    fn quantized_model_tracks_dense_model() {
        let w = tiny();
        let mut dense = LlamaModel::load(&w, EngineKind::Dense, None);
        let cfg = QuantConfig::new(4, 2, 8, 32).unwrap();
        let mut quant = LlamaModel::load(&w, EngineKind::codegemm(cfg), None);
        let mut cd = dense.new_cache();
        let mut cq = quant.new_cache();
        let ld = dense.prefill(&[5, 6, 7], &mut cd);
        let lq = quant.prefill(&[5, 6, 7], &mut cq);
        // ~4-bit-class quantization: logits correlated but not equal.
        let rel = stats::rel_l2(&lq, &ld);
        assert!(rel < 0.7, "quantized logits diverged: rel {rel}");
        assert!(rel > 1e-6, "quantized logits suspiciously identical");
    }

    #[test]
    fn parallel_dense_model_matches_serial_closely() {
        let w = tiny();
        let mut serial = LlamaModel::load(&w, EngineKind::Dense, None);
        let par = ParallelConfig { num_threads: 4, shard_min_rows: 16, ..Default::default() };
        let pool = Arc::new(ThreadPool::new(4));
        let mut sharded = LlamaModel::load_parallel(&w, EngineKind::Dense, None, &par, pool);
        let mut cs = serial.new_cache();
        let mut cp = sharded.new_cache();
        let ls = serial.prefill(&[5, 6, 7], &mut cs);
        let lp = sharded.prefill(&[5, 6, 7], &mut cp);
        // Column-parallel layers are bit-exact; row-parallel (wo/w_down)
        // reassociate the k-sum, so allow float noise only.
        let rel = crate::util::stats::rel_l2(&lp, &ls);
        assert!(rel < 1e-5, "parallel vs serial rel {rel}");
    }

    #[test]
    fn parallel_model_is_deterministic() {
        let w = tiny();
        let par = ParallelConfig { num_threads: 3, shard_min_rows: 16, ..Default::default() };
        let run = || {
            let pool = Arc::new(ThreadPool::new(3));
            let mut m = LlamaModel::load_parallel(&w, EngineKind::Dense, None, &par, pool);
            let mut c = m.new_cache();
            m.prefill(&[10, 20, 30], &mut c)
        };
        // Ordered reduction ⇒ bitwise identical across runs and schedules.
        assert_eq!(run(), run());
    }

    #[test]
    fn parallel_quantized_model_matches_serial_quantized() {
        let w = tiny();
        let cfg = QuantConfig::new(4, 1, 6, 32).unwrap();
        let kind = EngineKind::codegemm(cfg);
        let mut serial = LlamaModel::load(&w, kind, None);
        let par = ParallelConfig { num_threads: 2, shard_min_rows: 16, ..Default::default() };
        let pool = Arc::new(ThreadPool::new(2));
        let mut sharded = LlamaModel::load_parallel(&w, kind, None, &par, pool);
        let mut cs = serial.new_cache();
        let mut cp = sharded.new_cache();
        let ls = serial.prefill(&[3, 4], &mut cs);
        let lp = sharded.prefill(&[3, 4], &mut cp);
        // Same quantized weights (sharding happens after quantization);
        // only the row-parallel reassociation differs.
        let rel = crate::util::stats::rel_l2(&lp, &ls);
        assert!(rel < 1e-4, "parallel quantized vs serial rel {rel}");
        assert!(sharded.kind_label.contains("shard2"), "{}", sharded.kind_label);
    }

    /// The fused-projection toggle changes the *schedule*, never the
    /// weights (joint quantization happens either way), so logits are
    /// bit-identical with it on and off — while the fused model pays
    /// 3× / 2× fewer Psumbook builds per layer.
    #[test]
    fn fused_projections_bit_exact_and_build_macs_drop() {
        let w = tiny();
        let cfg = QuantConfig::new(4, 1, 6, 32).unwrap();
        let kind = EngineKind::codegemm(cfg);
        let prompt = [5usize, 99, 7];
        let run = |fused: bool| {
            let mut m = LlamaModel::load_with_options(&w, kind, None, fused);
            assert_eq!(m.uses_fused_projections(), fused);
            let mut c = m.new_cache();
            let logits = m.prefill(&prompt, &mut c);
            let counters = m.total_counters();
            (logits, counters)
        };
        let (l_on, c_on) = run(true);
        let (l_off, c_off) = run(false);
        assert_eq!(l_on, l_off, "fused and unfused schedules must agree bitwise");
        // Regression pin for the group factor: per layer the unfused
        // forward pays 2 extra Q/K/V builds + 1 extra gate/up build —
        // i.e. 3 extra full k-sweeps of `k·m·2^b·M` build MACs each
        // (every member sees the same reduction dim `d` and one prefill
        // chunk of M = prompt_len columns). Gather work is conserved.
        let sweep = (w.cfg.hidden * cfg.m * cfg.n_centroids() * prompt.len()) as u64;
        assert_eq!(
            c_off.build_ops - c_on.build_ops,
            (w.cfg.n_layers as u64) * 3 * sweep,
            "unfused {} vs fused {} build MACs",
            c_off.build_ops,
            c_on.build_ops
        );
        assert_eq!(c_on.read_ops, c_off.read_ops, "gather work must be conserved");
        assert!(c_on.group_fanout > 0 && c_off.group_fanout == 0);
    }

    #[test]
    fn fused_projections_bit_exact_under_sharding() {
        let w = tiny();
        let cfg = QuantConfig::new(4, 1, 6, 32).unwrap();
        let kind = EngineKind::codegemm(cfg);
        let prompt = [3usize, 4, 11];
        let run = |fused: bool| {
            let par = ParallelConfig {
                num_threads: 3,
                shard_min_rows: 16,
                fused_projections: fused,
                ..Default::default()
            };
            let pool = Arc::new(ThreadPool::new(3));
            let mut m = LlamaModel::load_parallel(&w, kind, None, &par, pool);
            let mut c = m.new_cache();
            m.prefill(&prompt, &mut c)
        };
        // Sharded fused vs sharded unfused: same joint quantization, the
        // book is bit-identical however many members/shards gather it.
        assert_eq!(run(true), run(false), "sharded fused forward diverged");
    }

    #[test]
    fn counters_accumulate_per_token() {
        let w = tiny();
        let mut m = LlamaModel::load(&w, EngineKind::codegemm(QuantConfig::m1v4g128()), None);
        let mut c = m.new_cache();
        m.forward(1, 0, &mut c);
        let after_one = m.total_counters().calls;
        assert!(after_one > 0, "forward must drive engine calls through the scratch");
        m.forward(2, 1, &mut c);
        let after_two = m.total_counters().calls;
        assert_eq!(after_two, 2 * after_one);
    }

    #[test]
    fn decode_scratch_reaches_steady_state() {
        // After the first decode token, further tokens must not grow any
        // model-owned buffer (the zero-allocation hot loop).
        let w = tiny();
        let mut m = LlamaModel::load(&w, EngineKind::codegemm(QuantConfig::m1v4g128()), None);
        let mut c = m.new_cache();
        let mut logits = vec![0f32; m.cfg.vocab];
        m.forward_into(1, 0, &mut c, &mut logits);
        let fp = |s: &ForwardScratch| {
            s.h.capacity()
                + s.normed.capacity()
                + s.q.capacity()
                + s.k.capacity()
                + s.v.capacity()
                + s.attn_out.capacity()
                + s.proj.capacity()
                + s.gate.capacity()
                + s.up.capacity()
                + s.act.capacity()
                + s.scores.capacity()
                + s.attn.k.capacity()
                + s.attn.v.capacity()
                + s.eng.footprint_bytes()
        };
        let warm = fp(&m.scratch);
        for pos in 1..5 {
            m.forward_into(pos, pos, &mut c, &mut logits);
        }
        assert_eq!(fp(&m.scratch), warm, "decode hot loop grew a buffer");
    }
}
