//! Pure-Rust Llama-style decoder running every linear layer through a
//! pluggable [`GemmEngine`] — the accuracy-evaluation substrate for
//! Tables 4/5 and Figure 4(b): the *same* model weights are loaded under
//! fp32, CodeGEMM, dequant, uniform or LUT engines and compared.
//!
//! Architecture (matches `python/compile/model.py` exactly): token
//! embedding → N × [RMSNorm → GQA attention with RoPE → residual →
//! RMSNorm → SwiGLU MLP → residual] → RMSNorm → LM head.

use super::engine_factory::EngineKind;
use super::kv::KvCache;
use super::weights::ModelWeights;
use crate::config::{ModelConfig, ParallelConfig};
use crate::gemm::GemmEngine;
use crate::parallel::ShardPlan;
use crate::util::stats::softmax_inplace;
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Engines for one decoder layer.
struct LayerEngines {
    wq: Box<dyn GemmEngine + Send>,
    wk: Box<dyn GemmEngine + Send>,
    wv: Box<dyn GemmEngine + Send>,
    wo: Box<dyn GemmEngine + Send>,
    w_gate: Box<dyn GemmEngine + Send>,
    w_up: Box<dyn GemmEngine + Send>,
    w_down: Box<dyn GemmEngine + Send>,
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
}

/// A Llama model whose linears run through a chosen kernel engine.
pub struct LlamaModel {
    pub cfg: ModelConfig,
    pub kind_label: String,
    embedding: Vec<f32>,
    layers: Vec<LayerEngines>,
    final_norm: Vec<f32>,
    lm_head: Box<dyn GemmEngine + Send>,
    /// Precomputed RoPE tables: `cos/sin[pos * half + i]`.
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
}

/// Precompute RoPE tables (`cos/sin[pos * half + i]`).
fn rope_tables(cfg: &ModelConfig) -> (Vec<f32>, Vec<f32>) {
    let hd = cfg.head_dim();
    let half = hd / 2;
    let mut rope_cos = vec![0f32; cfg.max_seq * half];
    let mut rope_sin = vec![0f32; cfg.max_seq * half];
    for pos in 0..cfg.max_seq {
        for i in 0..half {
            let freq = 1.0 / cfg.rope_theta().powf(2.0 * i as f32 / hd as f32);
            let angle = pos as f32 * freq;
            rope_cos[pos * half + i] = angle.cos();
            rope_sin[pos * half + i] = angle.sin();
        }
    }
    (rope_cos, rope_sin)
}

/// RMS normalization: `y = x * w / rms(x)`.
pub fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// SiLU activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Apply RoPE to `x` (heads of `head_dim`, rotate-half convention matching
/// `python/compile/model.py`).
pub fn rope_rotate(x: &mut [f32], head_dim: usize, cos: &[f32], sin: &[f32]) {
    let half = head_dim / 2;
    for head in x.chunks_mut(head_dim) {
        for i in 0..half {
            let (a, b) = (head[i], head[half + i]);
            head[i] = a * cos[i] - b * sin[i];
            head[half + i] = b * cos[i] + a * sin[i];
        }
    }
}

impl LlamaModel {
    /// Quantize (if applicable) and load `weights` under engine `kind`.
    /// `calib` optionally provides per-linear column importances keyed by
    /// the same order as `ModelWeights::linears()`.
    pub fn load(weights: &ModelWeights, kind: EngineKind, calib: Option<&[Vec<f32>]>) -> LlamaModel {
        let cfg = weights.cfg.clone();
        let d = cfg.hidden;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        let mut li = 0usize;
        let h = |i: &mut usize| -> Option<&[f32]> {
            let r = calib.map(|c| c[*i].as_slice());
            *i += 1;
            r
        };
        for l in &weights.layers {
            let kv = cfg.kv_dim();
            layers.push(LayerEngines {
                wq: kind.build(&l.wq, d, d, h(&mut li)),
                wk: kind.build(&l.wk, kv, d, h(&mut li)),
                wv: kind.build(&l.wv, kv, d, h(&mut li)),
                wo: kind.build(&l.wo, d, d, h(&mut li)),
                w_gate: kind.build(&l.w_gate, cfg.ffn, d, h(&mut li)),
                w_up: kind.build(&l.w_up, cfg.ffn, d, h(&mut li)),
                w_down: kind.build(&l.w_down, d, cfg.ffn, h(&mut li)),
                attn_norm: l.attn_norm.clone(),
                mlp_norm: l.mlp_norm.clone(),
            });
        }
        let lm_head = kind.build(&weights.lm_head, cfg.vocab, d, h(&mut li));
        let (rope_cos, rope_sin) = rope_tables(&cfg);
        LlamaModel {
            kind_label: kind.label(),
            embedding: weights.embedding.clone(),
            layers,
            final_norm: weights.final_norm.clone(),
            lm_head,
            rope_cos,
            rope_sin,
            cfg,
        }
    }

    /// Tensor-parallel load: every linear is sharded across `pool`
    /// according to `par`, per layer class:
    ///
    /// - Q/K/V, gate/up and the LM head are **column-parallel** (output
    ///   rows sharded, outputs concatenated — bit-exact vs. serial);
    /// - O and down are **row-parallel** (reduction dim sharded,
    ///   partials combined by the deterministic ordered all-reduce —
    ///   deterministic, equal to serial up to float reassociation).
    ///
    /// Every shard engine keeps its own Psumbook/LUT scratch, mirroring
    /// the per-thread-block tables of the GPU kernels.
    pub fn load_parallel(
        weights: &ModelWeights,
        kind: EngineKind,
        calib: Option<&[Vec<f32>]>,
        par: &ParallelConfig,
        pool: Arc<ThreadPool>,
    ) -> LlamaModel {
        let cfg = weights.cfg.clone();
        let d = cfg.hidden;
        let threads = par.effective_threads();
        let min = par.shard_min_rows;
        // Column-parallel (output-dim) builder for one linear.
        let col = |w: &[f32], n: usize, k: usize, h: Option<&[f32]>, on: bool| {
            if on {
                let plan = ShardPlan::new(n, threads, min, 1);
                kind.build_sharded(w, n, k, h, &plan, Arc::clone(&pool))
            } else {
                kind.build(w, n, k, h)
            }
        };
        // Row-parallel (reduction-dim) builder for one linear.
        let row = |w: &[f32], n: usize, k: usize, h: Option<&[f32]>, on: bool| {
            if on {
                let plan = ShardPlan::new(k, threads, min, kind.k_shard_align(k));
                kind.build_row_sharded(w, n, k, h, &plan, Arc::clone(&pool))
            } else {
                kind.build(w, n, k, h)
            }
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        let mut li = 0usize;
        let h = |i: &mut usize| -> Option<&[f32]> {
            let r = calib.map(|c| c[*i].as_slice());
            *i += 1;
            r
        };
        for l in &weights.layers {
            let kv = cfg.kv_dim();
            layers.push(LayerEngines {
                wq: col(&l.wq, d, d, h(&mut li), par.shard_attn),
                wk: col(&l.wk, kv, d, h(&mut li), par.shard_attn),
                wv: col(&l.wv, kv, d, h(&mut li), par.shard_attn),
                wo: row(&l.wo, d, d, h(&mut li), par.shard_attn),
                w_gate: col(&l.w_gate, cfg.ffn, d, h(&mut li), par.shard_mlp),
                w_up: col(&l.w_up, cfg.ffn, d, h(&mut li), par.shard_mlp),
                w_down: row(&l.w_down, d, cfg.ffn, h(&mut li), par.shard_mlp),
                attn_norm: l.attn_norm.clone(),
                mlp_norm: l.mlp_norm.clone(),
            });
        }
        let lm_head = col(&weights.lm_head, cfg.vocab, d, h(&mut li), par.shard_lm_head);
        let (rope_cos, rope_sin) = rope_tables(&cfg);
        LlamaModel {
            kind_label: format!("{}+shard{}", kind.label(), threads),
            embedding: weights.embedding.clone(),
            layers,
            final_norm: weights.final_norm.clone(),
            lm_head,
            rope_cos,
            rope_sin,
            cfg,
        }
    }

    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.cfg.n_layers, self.cfg.max_seq, self.cfg.kv_dim())
    }

    /// One decode step: token at position `pos` → logits over the vocab.
    /// Appends this position's K/V to `cache`.
    pub fn forward(&mut self, token: usize, pos: usize, cache: &mut KvCache) -> Vec<f32> {
        let cfg = &self.cfg;
        let d = cfg.hidden;
        let hd = cfg.head_dim();
        let kv_dim = cfg.kv_dim();
        let groups = cfg.n_heads / cfg.n_kv_heads;
        assert!(token < cfg.vocab, "token {token} out of vocab");

        let mut h = self.embedding[token * d..(token + 1) * d].to_vec();
        let mut normed = vec![0f32; d];
        let half = hd / 2;
        let cos = self.rope_cos[pos * half..(pos + 1) * half].to_vec();
        let sin = self.rope_sin[pos * half..(pos + 1) * half].to_vec();
        for (layer_i, l) in self.layers.iter_mut().enumerate() {
            // ---- attention ----
            rmsnorm(&h, &l.attn_norm, &mut normed);
            let mut q = l.wq.gemv(&normed);
            let mut k = l.wk.gemv(&normed);
            let v = l.wv.gemv(&normed);
            rope_rotate(&mut q, hd, &cos, &sin);
            rope_rotate(&mut k, hd, &cos, &sin);
            cache.write(layer_i, pos, &k, &v);
            let upto = pos + 1;
            let keys = cache.keys(layer_i, upto).to_vec();
            let vals = cache.values(layer_i, upto).to_vec();
            let mut attn_out = vec![0f32; d];
            let scale = 1.0 / (hd as f32).sqrt();
            let mut scores = vec![0f32; upto];
            for head in 0..cfg.n_heads {
                let kv_head = head / groups;
                let qh = &q[head * hd..(head + 1) * hd];
                for (p, s) in scores.iter_mut().enumerate() {
                    let kh = &keys[p * kv_dim + kv_head * hd..p * kv_dim + (kv_head + 1) * hd];
                    *s = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                softmax_inplace(&mut scores);
                let out = &mut attn_out[head * hd..(head + 1) * hd];
                for (p, &s) in scores.iter().enumerate() {
                    let vh = &vals[p * kv_dim + kv_head * hd..p * kv_dim + (kv_head + 1) * hd];
                    for t in 0..hd {
                        out[t] += s * vh[t];
                    }
                }
            }
            let proj = l.wo.gemv(&attn_out);
            for i in 0..d {
                h[i] += proj[i];
            }
            // ---- MLP ----
            rmsnorm(&h, &l.mlp_norm, &mut normed);
            let gate = l.w_gate.gemv(&normed);
            let up = l.w_up.gemv(&normed);
            let act: Vec<f32> = gate.iter().zip(&up).map(|(&g, &u)| silu(g) * u).collect();
            let down = l.w_down.gemv(&act);
            for i in 0..d {
                h[i] += down[i];
            }
        }
        rmsnorm(&h.clone(), &self.final_norm, &mut h);
        self.lm_head.gemv(&h)
    }

    /// Run a whole prompt, returning logits after the final token.
    pub fn prefill(&mut self, tokens: &[usize], cache: &mut KvCache) -> Vec<f32> {
        let mut logits = Vec::new();
        for (pos, &t) in tokens.iter().enumerate() {
            logits = self.forward(t, pos, cache);
        }
        logits
    }

    /// Sum of work/traffic counters across every engine in the model.
    pub fn total_counters(&self) -> crate::gemm::Counters {
        let mut total = crate::gemm::Counters::new();
        let mut add = |c: &crate::gemm::Counters| {
            total.mac_flops += c.mac_flops;
            total.lookups += c.lookups;
            total.weight_bytes += c.weight_bytes;
            total.activation_bytes += c.activation_bytes;
            total.scratch_bytes += c.scratch_bytes;
            total.build_ops += c.build_ops;
            total.read_ops += c.read_ops;
            total.build_seconds += c.build_seconds;
            total.read_seconds += c.read_seconds;
            total.calls += c.calls;
        };
        for l in &self.layers {
            for e in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up, &l.w_down] {
                add(e.counters());
            }
        }
        add(self.lm_head.counters());
        total
    }

    /// Total quantized storage of all linear engines would occupy, bytes
    /// (approximated from the per-layer dims × the engine's bit rate).
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, QuantConfig};
    use crate::util::stats;

    fn tiny() -> ModelWeights {
        ModelWeights::random(ModelConfig::tiny(), 42)
    }

    #[test]
    fn forward_produces_finite_logits() {
        let w = tiny();
        let mut m = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut cache = m.new_cache();
        let logits = m.forward(65, 0, &mut cache);
        assert_eq!(logits.len(), w.cfg.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(cache.len, 1);
    }

    #[test]
    fn deterministic_across_runs() {
        let w = tiny();
        let run = || {
            let mut m = LlamaModel::load(&w, EngineKind::Dense, None);
            let mut c = m.new_cache();
            m.prefill(&[10, 20, 30], &mut c)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn kv_cache_consistent_with_recompute() {
        // Decoding [a, b, c] step-by-step must equal prefilling [a, b, c].
        let w = tiny();
        let mut m1 = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut c1 = m1.new_cache();
        let l1 = m1.prefill(&[7, 8, 9], &mut c1);
        let mut m2 = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut c2 = m2.new_cache();
        m2.forward(7, 0, &mut c2);
        m2.forward(8, 1, &mut c2);
        let l2 = m2.forward(9, 2, &mut c2);
        assert!(stats::rel_l2(&l1, &l2) < 1e-6);
    }

    #[test]
    fn attention_attends_to_history() {
        // Changing an *earlier* token must change later logits (the cache
        // is actually read).
        let w = tiny();
        let mut m = LlamaModel::load(&w, EngineKind::Dense, None);
        let mut ca = m.new_cache();
        let la = m.prefill(&[1, 2, 3], &mut ca);
        let mut cb = m.new_cache();
        let lb = m.prefill(&[200, 2, 3], &mut cb);
        assert!(stats::rel_l2(&la, &lb) > 1e-4, "history must influence logits");
    }

    #[test]
    fn quantized_model_tracks_dense_model() {
        let w = tiny();
        let mut dense = LlamaModel::load(&w, EngineKind::Dense, None);
        let cfg = QuantConfig::new(4, 2, 8, 32).unwrap();
        let mut quant = LlamaModel::load(&w, EngineKind::codegemm(cfg), None);
        let mut cd = dense.new_cache();
        let mut cq = quant.new_cache();
        let ld = dense.prefill(&[5, 6, 7], &mut cd);
        let lq = quant.prefill(&[5, 6, 7], &mut cq);
        // ~4-bit-class quantization: logits correlated but not equal.
        let rel = stats::rel_l2(&lq, &ld);
        assert!(rel < 0.7, "quantized logits diverged: rel {rel}");
        assert!(rel > 1e-6, "quantized logits suspiciously identical");
    }

    #[test]
    fn parallel_dense_model_matches_serial_closely() {
        let w = tiny();
        let mut serial = LlamaModel::load(&w, EngineKind::Dense, None);
        let par = ParallelConfig { num_threads: 4, shard_min_rows: 16, ..Default::default() };
        let pool = Arc::new(ThreadPool::new(4));
        let mut sharded = LlamaModel::load_parallel(&w, EngineKind::Dense, None, &par, pool);
        let mut cs = serial.new_cache();
        let mut cp = sharded.new_cache();
        let ls = serial.prefill(&[5, 6, 7], &mut cs);
        let lp = sharded.prefill(&[5, 6, 7], &mut cp);
        // Column-parallel layers are bit-exact; row-parallel (wo/w_down)
        // reassociate the k-sum, so allow float noise only.
        let rel = crate::util::stats::rel_l2(&lp, &ls);
        assert!(rel < 1e-5, "parallel vs serial rel {rel}");
    }

    #[test]
    fn parallel_model_is_deterministic() {
        let w = tiny();
        let par = ParallelConfig { num_threads: 3, shard_min_rows: 16, ..Default::default() };
        let run = || {
            let pool = Arc::new(ThreadPool::new(3));
            let mut m = LlamaModel::load_parallel(&w, EngineKind::Dense, None, &par, pool);
            let mut c = m.new_cache();
            m.prefill(&[10, 20, 30], &mut c)
        };
        // Ordered reduction ⇒ bitwise identical across runs and schedules.
        assert_eq!(run(), run());
    }

    #[test]
    fn parallel_quantized_model_matches_serial_quantized() {
        let w = tiny();
        let cfg = QuantConfig::new(4, 1, 6, 32).unwrap();
        let kind = EngineKind::codegemm(cfg);
        let mut serial = LlamaModel::load(&w, kind, None);
        let par = ParallelConfig { num_threads: 2, shard_min_rows: 16, ..Default::default() };
        let pool = Arc::new(ThreadPool::new(2));
        let mut sharded = LlamaModel::load_parallel(&w, kind, None, &par, pool);
        let mut cs = serial.new_cache();
        let mut cp = sharded.new_cache();
        let ls = serial.prefill(&[3, 4], &mut cs);
        let lp = sharded.prefill(&[3, 4], &mut cp);
        // Same quantized weights (sharding happens after quantization);
        // only the row-parallel reassociation differs.
        let rel = crate::util::stats::rel_l2(&lp, &ls);
        assert!(rel < 1e-4, "parallel quantized vs serial rel {rel}");
        assert!(sharded.kind_label.contains("shard2"), "{}", sharded.kind_label);
    }

    #[test]
    fn counters_accumulate_per_token() {
        let w = tiny();
        let mut m = LlamaModel::load(&w, EngineKind::codegemm(QuantConfig::m1v4g128()), None);
        let mut c = m.new_cache();
        m.forward(1, 0, &mut c);
        let after_one = m.total_counters().calls;
        m.forward(2, 1, &mut c);
        let after_two = m.total_counters().calls;
        assert_eq!(after_two, 2 * after_one);
    }
}
