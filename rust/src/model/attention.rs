//! Chunked GQA attention over a tiled KV cache.
//!
//! The kernel walks the cache tile-by-tile through
//! [`crate::kvcache::KvStore`] — page-sized tiles for the paged pool, one
//! whole-cache tile for the contiguous [`super::KvCache`] — in two
//! passes:
//!
//! 1. **scores**: `q · k` for every cached position and every head,
//!    written into the caller's scores scratch (one `upto`-long row per
//!    head), then a single softmax per head over `0..upto`;
//! 2. **values**: the softmax-weighted V accumulation into each output
//!    head.
//!
//! Both passes iterate **tiles outer, heads inner**: each tile is
//! resolved through [`KvStore::tile`] exactly once per pass and its
//! contiguous K (resp. V) rows are reused by every head — `2 × n_tiles`
//! page-table resolutions per call, not `2 × n_heads × n_tiles` (the
//! paged store walks a page table per resolution, so the head loop was
//! multiplying pure bookkeeping). Per (head, position) the float ops and
//! their order are identical to the flat loop this kernel replaced in
//! `llama.rs` — positions ascend within each head in both passes — so
//! the result stays **bit-exact** for any tile size (property-pinned by
//! `tests/paged_kv_prop.rs` across page sizes × heads × prompt lengths).
//! Two passes were chosen over online softmax precisely to keep that
//! guarantee — the scores buffer is `n_heads × max_seq` floats of reused
//! scratch ([`AttnShape::scores_len`]), which is noise next to the cache
//! itself.
//!
//! Used by both the decode step (`m = 1`) and batched prefill (causal:
//! position `pos0 + b` attends to `0..=pos0 + b`, all already appended).

use crate::config::ModelConfig;
use crate::kvcache::KvStore;
use crate::util::stats::softmax_inplace;

/// Head geometry for one attention call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttnShape {
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl AttnShape {
    pub fn of(cfg: &ModelConfig) -> AttnShape {
        AttnShape { n_heads: cfg.n_heads, n_kv_heads: cfg.n_kv_heads, head_dim: cfg.head_dim() }
    }

    /// Query heads per KV head (GQA group width).
    pub fn groups(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Scores-scratch length [`attend`] needs for a call over `upto`
    /// positions: one row per query head (size the buffer once with
    /// `scores_len(max_seq)`).
    pub fn scores_len(&self, upto: usize) -> usize {
        self.n_heads * upto
    }
}

/// One query position's GQA attention against `kv` positions `0..upto`
/// of `layer`.
///
/// - `q`: the RoPE-rotated query row (`n_heads × head_dim`);
/// - `scores`: caller scratch, at least [`AttnShape::scores_len`]
///   (`n_heads × upto`) long (overwritten) — one row per head, so the
///   tile loop can sit outside the head loop;
/// - `out`: the attention output row (`n_heads × head_dim`, overwritten).
pub fn attend<C: KvStore + ?Sized>(
    kv: &C,
    layer: usize,
    shape: &AttnShape,
    q: &[f32],
    upto: usize,
    scale: f32,
    scores: &mut [f32],
    out: &mut [f32],
) {
    let hd = shape.head_dim;
    let kv_dim = shape.kv_dim();
    let groups = shape.groups();
    debug_assert!(upto >= 1 && upto <= kv.max_seq());
    debug_assert_eq!(q.len(), shape.n_heads * hd);
    debug_assert_eq!(out.len(), shape.n_heads * hd);
    debug_assert!(scores.len() >= shape.scores_len(upto));
    let tt = kv.tile_tokens();
    let n_tiles = kv.n_tiles(upto);
    let sc = &mut scores[..shape.n_heads * upto];
    out.fill(0.0);
    // Pass 1: raw scores — tiles outer, so each tile (one page-table
    // resolution on the paged store) serves every head; per head,
    // positions are still visited in ascending order.
    for t in 0..n_tiles {
        let (keys, _) = kv.tile(layer, t, upto);
        let p0 = t * tt;
        let n_in = keys.len() / kv_dim;
        for head in 0..shape.n_heads {
            let kv_head = head / groups;
            let qh = &q[head * hd..(head + 1) * hd];
            let sc_h = &mut sc[head * upto..(head + 1) * upto];
            for j in 0..n_in {
                let kh = &keys[j * kv_dim + kv_head * hd..j * kv_dim + (kv_head + 1) * hd];
                sc_h[p0 + j] = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
        }
    }
    for head in 0..shape.n_heads {
        softmax_inplace(&mut sc[head * upto..(head + 1) * upto]);
    }
    // Pass 2: softmax-weighted V accumulation, tiles outer again; each
    // output head still accumulates positions in ascending order, so
    // the result is bit-exact vs. the heads-outer loop this replaced.
    for t in 0..n_tiles {
        let (_, vals) = kv.tile(layer, t, upto);
        let p0 = t * tt;
        let n_in = vals.len() / kv_dim;
        for head in 0..shape.n_heads {
            let kv_head = head / groups;
            let sc_h = &sc[head * upto..(head + 1) * upto];
            let oh = &mut out[head * hd..(head + 1) * hd];
            for j in 0..n_in {
                let w = sc_h[p0 + j];
                let vh = &vals[j * kv_dim + kv_head * hd..j * kv_dim + (kv_head + 1) * hd];
                for x in 0..hd {
                    oh[x] += w * vh[x];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{BlockPool, KvLayout, PagedKv, SeqKv};
    use crate::model::KvCache;
    use crate::util::prng::Prng;

    /// The flat reference loop the kernel replaced (pre-extraction
    /// `llama.rs` attention body, verbatim math).
    fn attend_flat(
        cache: &KvCache,
        layer: usize,
        shape: &AttnShape,
        q: &[f32],
        upto: usize,
        scale: f32,
        scores: &mut [f32],
        out: &mut [f32],
    ) {
        let hd = shape.head_dim;
        let kv_dim = shape.kv_dim();
        let groups = shape.groups();
        let keys = cache.keys(layer, upto);
        let vals = cache.values(layer, upto);
        let sc = &mut scores[..upto];
        out.fill(0.0);
        for head in 0..shape.n_heads {
            let kv_head = head / groups;
            let qh = &q[head * hd..(head + 1) * hd];
            for (p, scv) in sc.iter_mut().enumerate() {
                let kh = &keys[p * kv_dim + kv_head * hd..p * kv_dim + (kv_head + 1) * hd];
                *scv = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            softmax_inplace(sc);
            let oh = &mut out[head * hd..(head + 1) * hd];
            for (p, &scv) in sc.iter().enumerate() {
                let vh = &vals[p * kv_dim + kv_head * hd..p * kv_dim + (kv_head + 1) * hd];
                for x in 0..hd {
                    oh[x] += scv * vh[x];
                }
            }
        }
    }

    fn fill_both(
        rng: &mut Prng,
        cache: &mut KvCache,
        paged: &mut PagedKv<'_>,
        n_layers: usize,
        kv_dim: usize,
        positions: usize,
    ) {
        for pos in 0..positions {
            for layer in 0..n_layers {
                let k = rng.normal_vec(kv_dim, 1.0);
                let v = rng.normal_vec(kv_dim, 1.0);
                cache.write(layer, pos, &k, &v);
                paged.write(layer, pos, &k, &v);
            }
        }
    }

    #[test]
    fn tiled_attention_bit_exact_vs_flat_for_any_page_size() {
        let shape = AttnShape { n_heads: 4, n_kv_heads: 2, head_dim: 8 };
        let kv_dim = shape.kv_dim();
        let (n_layers, max_seq) = (2, 40);
        let scale = 1.0 / (shape.head_dim as f32).sqrt();
        for page_size in [1usize, 3, 4, 7, 16, 64] {
            let layout = KvLayout { n_layers, kv_dim, page_size, max_seq };
            let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
            let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
            let mut cache = KvCache::new(n_layers, max_seq, kv_dim);
            let mut paged = PagedKv::bind(&mut pool, &mut seq);
            let mut rng = Prng::seeded(7 + page_size as u64);
            // Lengths straddling page boundaries on purpose.
            fill_both(&mut rng, &mut cache, &mut paged, n_layers, kv_dim, 37);
            let q = rng.normal_vec(shape.n_heads * shape.head_dim, 1.0);
            let mut flat_scores = vec![0f32; max_seq];
            let mut scores = vec![0f32; shape.scores_len(max_seq)];
            let mut a = vec![0f32; q.len()];
            let mut b = vec![0f32; q.len()];
            let mut c = vec![0f32; q.len()];
            for upto in [1usize, page_size.min(37), 17, 36, 37] {
                for layer in 0..n_layers {
                    attend_flat(&cache, layer, &shape, &q, upto, scale, &mut flat_scores, &mut a);
                    attend(&cache, layer, &shape, &q, upto, scale, &mut scores, &mut b);
                    attend(&paged, layer, &shape, &q, upto, scale, &mut scores, &mut c);
                    assert_eq!(a, b, "contiguous tiled != flat (page {page_size}, upto {upto})");
                    assert_eq!(a, c, "paged tiled != flat (page {page_size}, upto {upto})");
                }
            }
        }
    }

    #[test]
    fn mqa_and_mha_group_widths() {
        // groups = n_heads (MQA, one KV head) and groups = 1 (MHA).
        for (n_heads, n_kv_heads) in [(4, 1), (4, 4)] {
            let shape = AttnShape { n_heads, n_kv_heads, head_dim: 4 };
            let kv_dim = shape.kv_dim();
            let layout = KvLayout { n_layers: 1, kv_dim, page_size: 2, max_seq: 8 };
            let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
            let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
            let mut cache = KvCache::new(1, 8, kv_dim);
            let mut paged = PagedKv::bind(&mut pool, &mut seq);
            let mut rng = Prng::seeded(11);
            fill_both(&mut rng, &mut cache, &mut paged, 1, kv_dim, 5);
            let q = rng.normal_vec(n_heads * 4, 1.0);
            let mut flat_scores = vec![0f32; 8];
            let mut scores = vec![0f32; shape.scores_len(8)];
            let (mut a, mut b) = (vec![0f32; q.len()], vec![0f32; q.len()]);
            attend_flat(&cache, 0, &shape, &q, 5, 0.5, &mut flat_scores, &mut a);
            attend(&paged, 0, &shape, &q, 5, 0.5, &mut scores, &mut b);
            assert_eq!(a, b);
        }
    }
}
