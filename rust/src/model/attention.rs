//! Chunked GQA attention over a tiled, possibly *coded* KV cache.
//!
//! The kernels walk the cache tile-by-tile through
//! [`crate::kvcache::KvStore`] — page-sized tiles for the paged pool, one
//! whole-cache tile for the contiguous [`super::KvCache`] — in two
//! passes:
//!
//! 1. **scores**: `q · k` for every cached position and every head,
//!    written into the caller's scores scratch, then a single softmax per
//!    (query, head) over that query's causal range;
//! 2. **values**: the softmax-weighted V accumulation into each output
//!    head.
//!
//! Both passes iterate **tiles outer, heads inner**: each tile is
//! resolved (and, for coded dtypes, decoded) through
//! [`KvStore::k_tile`]/[`KvStore::v_tile`] exactly once per pass into the
//! caller's [`AttnScratch`], and the decoded rows are reused by every
//! head. Tile reads are the unit [`AttnScratch::tile_resolutions`]
//! counts: a page-table walk plus — for f16/int8 pools — a full tile
//! decode, so keeping resolutions at `2 × n_tiles` is what keeps coded
//! caches from decoding the same page over and over.
//!
//! [`attend`] handles one query position (the decode step). For prefill,
//! [`attend_batch`] takes all `m` freshly-appended query rows of a chunk
//! and walks each K/V tile **once for the whole chunk**: the tile loop
//! sits outside the query loop, computing a tile × queries score block
//! with the causal mask applied inside the tile walk (query `pos0 + b`
//! sees positions `0..=pos0 + b`). A chunk therefore costs
//! `2 × n_tiles(pos0 + m)` tile resolutions instead of the
//! `2 × Σ_b n_tiles(pos0 + b + 1)` the per-position loop paid — on a
//! coded pool that is the difference between decoding each page once and
//! decoding it `m` times per chunk.
//!
//! # Exactness
//!
//! Per (query, head, position) the float ops and their order are
//! identical between [`attend`], [`attend_batch`], and the flat loop the
//! kernels replaced — positions ascend within each query's head in both
//! passes, and the causal mask only *truncates* that ascending walk. So
//! for any tile size and chunk split, batched prefill is **bit-exact**
//! against the per-position walk over the *same store* in every KV dtype
//! (the per-tile decode is deterministic, so both kernels see identical
//! decoded floats). Versus an f32 store, coded dtypes carry the KV
//! codec's documented error (f16 rounding; int8 half-a-scale-step per
//! element) — see [`crate::kvcache`] for the per-dtype contract. Two
//! passes were chosen over online softmax precisely to keep the
//! bit-exactness guarantee.

use crate::config::ModelConfig;
use crate::kvcache::KvStore;
use crate::util::stats::softmax_inplace;

/// Head geometry for one attention call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttnShape {
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl AttnShape {
    pub fn of(cfg: &ModelConfig) -> AttnShape {
        AttnShape { n_heads: cfg.n_heads, n_kv_heads: cfg.n_kv_heads, head_dim: cfg.head_dim() }
    }

    /// Query heads per KV head (GQA group width).
    pub fn groups(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Scores-scratch length [`attend`] needs for a call over `upto`
    /// positions: one row per query head (size the buffer once with
    /// `scores_len(max_seq)`).
    pub fn scores_len(&self, upto: usize) -> usize {
        self.n_heads * upto
    }

    /// Scores-scratch length [`attend_batch`] needs for `m` queries whose
    /// last position is `upto_max - 1`: one `upto_max`-long row per
    /// (query, head).
    pub fn scores_len_batch(&self, m: usize, upto_max: usize) -> usize {
        m * self.n_heads * upto_max
    }
}

/// Per-call attention scratch: the K/V tile decode buffers (borrowed by
/// [`KvStore::k_tile`]/[`KvStore::v_tile`] when the backing is coded;
/// untouched for f32 pools, which hand out zero-copy borrows) plus a
/// tile-resolution counter.
///
/// The counter increments once per tile read — page-table walk + decode —
/// which is exactly the work batched prefill amortises: one [`attend`]
/// call costs `2 × n_tiles(upto)` resolutions, one [`attend_batch`] chunk
/// costs `2 × n_tiles(pos0 + m)` *total*, independent of `m`
/// (counter-pinned in tests and gated in `benches/scaling.rs`).
#[derive(Clone, Debug, Default)]
pub struct AttnScratch {
    /// Key-tile decode buffer.
    pub k: Vec<f32>,
    /// Value-tile decode buffer.
    pub v: Vec<f32>,
    /// Tile reads (K and V each count) since construction or
    /// [`Self::reset_tile_resolutions`].
    pub tile_resolutions: u64,
}

impl AttnScratch {
    pub fn new() -> AttnScratch {
        AttnScratch::default()
    }

    pub fn reset_tile_resolutions(&mut self) {
        self.tile_resolutions = 0;
    }
}

/// One query position's GQA attention against `kv` positions `0..upto`
/// of `layer`.
///
/// - `q`: the RoPE-rotated query row (`n_heads × head_dim`);
/// - `scratch`: tile decode buffers + resolution counter;
/// - `scores`: caller scratch, at least [`AttnShape::scores_len`]
///   (`n_heads × upto`) long (overwritten) — one row per head, so the
///   tile loop can sit outside the head loop;
/// - `out`: the attention output row (`n_heads × head_dim`, overwritten).
pub fn attend<C: KvStore + ?Sized>(
    kv: &C,
    layer: usize,
    shape: &AttnShape,
    q: &[f32],
    upto: usize,
    scale: f32,
    scratch: &mut AttnScratch,
    scores: &mut [f32],
    out: &mut [f32],
) {
    let hd = shape.head_dim;
    let kv_dim = shape.kv_dim();
    let groups = shape.groups();
    debug_assert!(upto >= 1 && upto <= kv.max_seq());
    debug_assert_eq!(q.len(), shape.n_heads * hd);
    debug_assert_eq!(out.len(), shape.n_heads * hd);
    debug_assert!(scores.len() >= shape.scores_len(upto));
    let tt = kv.tile_tokens();
    let n_tiles = kv.n_tiles(upto);
    let sc = &mut scores[..shape.n_heads * upto];
    out.fill(0.0);
    // Pass 1: raw scores — tiles outer, so each tile (one page-table
    // resolution + decode on a coded store) serves every head; per head,
    // positions are still visited in ascending order.
    for t in 0..n_tiles {
        scratch.tile_resolutions += 1;
        let keys = kv.k_tile(layer, t, upto, &mut scratch.k);
        let p0 = t * tt;
        let n_in = keys.len() / kv_dim;
        for head in 0..shape.n_heads {
            let kv_head = head / groups;
            let qh = &q[head * hd..(head + 1) * hd];
            let sc_h = &mut sc[head * upto..(head + 1) * upto];
            for j in 0..n_in {
                let kh = &keys[j * kv_dim + kv_head * hd..j * kv_dim + (kv_head + 1) * hd];
                sc_h[p0 + j] = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
        }
    }
    for head in 0..shape.n_heads {
        softmax_inplace(&mut sc[head * upto..(head + 1) * upto]);
    }
    // Pass 2: softmax-weighted V accumulation, tiles outer again; each
    // output head still accumulates positions in ascending order, so
    // the result is bit-exact vs. the heads-outer loop this replaced.
    for t in 0..n_tiles {
        scratch.tile_resolutions += 1;
        let vals = kv.v_tile(layer, t, upto, &mut scratch.v);
        let p0 = t * tt;
        let n_in = vals.len() / kv_dim;
        for head in 0..shape.n_heads {
            let kv_head = head / groups;
            let sc_h = &sc[head * upto..(head + 1) * upto];
            let oh = &mut out[head * hd..(head + 1) * hd];
            for j in 0..n_in {
                let w = sc_h[p0 + j];
                let vh = &vals[j * kv_dim + kv_head * hd..j * kv_dim + (kv_head + 1) * hd];
                for x in 0..hd {
                    oh[x] += w * vh[x];
                }
            }
        }
    }
}

/// Batched causal attention for one prefill chunk: queries at positions
/// `pos0..pos0 + m` (all of whose K/V rows are already appended to `kv`),
/// each attending to its own causal prefix `0..=pos0 + b`.
///
/// Walks each K/V tile once for the whole chunk — score blocks are
/// computed tile × queries with the causal mask applied as a truncation
/// of each query's in-tile range — so the chunk costs
/// `2 × n_tiles(pos0 + m)` tile resolutions total. Bit-exact against `m`
/// successive [`attend`] calls over the same store in every dtype (see
/// the module docs).
///
/// - `q`: `m` query rows, `m × n_heads × head_dim`;
/// - `scores`: at least [`AttnShape::scores_len_batch`]`(m, pos0 + m)`
///   long (overwritten);
/// - `out`: `m × n_heads × head_dim` (overwritten).
pub fn attend_batch<C: KvStore + ?Sized>(
    kv: &C,
    layer: usize,
    shape: &AttnShape,
    q: &[f32],
    pos0: usize,
    m: usize,
    scale: f32,
    scratch: &mut AttnScratch,
    scores: &mut [f32],
    out: &mut [f32],
) {
    let hd = shape.head_dim;
    let kv_dim = shape.kv_dim();
    let groups = shape.groups();
    let upto_max = pos0 + m;
    debug_assert!(m >= 1 && upto_max <= kv.max_seq());
    debug_assert_eq!(q.len(), m * shape.n_heads * hd);
    debug_assert_eq!(out.len(), m * shape.n_heads * hd);
    debug_assert!(scores.len() >= shape.scores_len_batch(m, upto_max));
    let tt = kv.tile_tokens();
    let n_tiles = kv.n_tiles(upto_max);
    let sc = &mut scores[..m * shape.n_heads * upto_max];
    out.fill(0.0);
    // Pass 1: tile × queries score blocks. The causal mask is a per-query
    // truncation of the in-tile range: query pos0 + b sees tile positions
    // p0..min(p0 + n_in, pos0 + b + 1).
    for t in 0..n_tiles {
        scratch.tile_resolutions += 1;
        let keys = kv.k_tile(layer, t, upto_max, &mut scratch.k);
        let p0 = t * tt;
        let n_in = keys.len() / kv_dim;
        for b in 0..m {
            let visible = pos0 + b + 1;
            if visible <= p0 {
                continue;
            }
            let limit = n_in.min(visible - p0);
            let qb = &q[b * shape.n_heads * hd..(b + 1) * shape.n_heads * hd];
            for head in 0..shape.n_heads {
                let kv_head = head / groups;
                let qh = &qb[head * hd..(head + 1) * hd];
                let row = (b * shape.n_heads + head) * upto_max;
                let sc_h = &mut sc[row..row + upto_max];
                for j in 0..limit {
                    let kh = &keys[j * kv_dim + kv_head * hd..j * kv_dim + (kv_head + 1) * hd];
                    sc_h[p0 + j] = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
            }
        }
    }
    for b in 0..m {
        let visible = pos0 + b + 1;
        for head in 0..shape.n_heads {
            let row = (b * shape.n_heads + head) * upto_max;
            softmax_inplace(&mut sc[row..row + visible]);
        }
    }
    // Pass 2: weighted V accumulation, tiles outer again; per (query,
    // head) positions still accumulate in ascending order.
    for t in 0..n_tiles {
        scratch.tile_resolutions += 1;
        let vals = kv.v_tile(layer, t, upto_max, &mut scratch.v);
        let p0 = t * tt;
        let n_in = vals.len() / kv_dim;
        for b in 0..m {
            let visible = pos0 + b + 1;
            if visible <= p0 {
                continue;
            }
            let limit = n_in.min(visible - p0);
            for head in 0..shape.n_heads {
                let kv_head = head / groups;
                let row = (b * shape.n_heads + head) * upto_max;
                let sc_h = &sc[row..row + upto_max];
                let oh = &mut out
                    [(b * shape.n_heads + head) * hd..(b * shape.n_heads + head + 1) * hd];
                for j in 0..limit {
                    let w = sc_h[p0 + j];
                    let vh = &vals[j * kv_dim + kv_head * hd..j * kv_dim + (kv_head + 1) * hd];
                    for x in 0..hd {
                        oh[x] += w * vh[x];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KvDtype;
    use crate::kvcache::{BlockPool, KvLayout, PagedKv, SeqKv};
    use crate::model::KvCache;
    use crate::util::prng::Prng;

    /// The flat reference loop the kernel replaced (pre-extraction
    /// `llama.rs` attention body, verbatim math).
    fn attend_flat(
        cache: &KvCache,
        layer: usize,
        shape: &AttnShape,
        q: &[f32],
        upto: usize,
        scale: f32,
        scores: &mut [f32],
        out: &mut [f32],
    ) {
        let hd = shape.head_dim;
        let kv_dim = shape.kv_dim();
        let groups = shape.groups();
        let keys = cache.keys(layer, upto);
        let vals = cache.values(layer, upto);
        let sc = &mut scores[..upto];
        out.fill(0.0);
        for head in 0..shape.n_heads {
            let kv_head = head / groups;
            let qh = &q[head * hd..(head + 1) * hd];
            for (p, scv) in sc.iter_mut().enumerate() {
                let kh = &keys[p * kv_dim + kv_head * hd..p * kv_dim + (kv_head + 1) * hd];
                *scv = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            softmax_inplace(sc);
            let oh = &mut out[head * hd..(head + 1) * hd];
            for (p, &scv) in sc.iter().enumerate() {
                let vh = &vals[p * kv_dim + kv_head * hd..p * kv_dim + (kv_head + 1) * hd];
                for x in 0..hd {
                    oh[x] += scv * vh[x];
                }
            }
        }
    }

    fn fill_both(
        rng: &mut Prng,
        cache: &mut KvCache,
        paged: &mut PagedKv<'_>,
        n_layers: usize,
        kv_dim: usize,
        positions: usize,
    ) {
        for pos in 0..positions {
            for layer in 0..n_layers {
                let k = rng.normal_vec(kv_dim, 1.0);
                let v = rng.normal_vec(kv_dim, 1.0);
                cache.write(layer, pos, &k, &v);
                paged.write(layer, pos, &k, &v);
            }
        }
    }

    #[test]
    fn tiled_attention_bit_exact_vs_flat_for_any_page_size() {
        let shape = AttnShape { n_heads: 4, n_kv_heads: 2, head_dim: 8 };
        let kv_dim = shape.kv_dim();
        let (n_layers, max_seq) = (2, 40);
        let scale = 1.0 / (shape.head_dim as f32).sqrt();
        for page_size in [1usize, 3, 4, 7, 16, 64] {
            let layout = KvLayout { n_layers, kv_dim, page_size, max_seq, dtype: KvDtype::F32 };
            let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
            let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
            let mut cache = KvCache::new(n_layers, max_seq, kv_dim);
            let mut paged = PagedKv::bind(&mut pool, &mut seq);
            let mut rng = Prng::seeded(7 + page_size as u64);
            // Lengths straddling page boundaries on purpose.
            fill_both(&mut rng, &mut cache, &mut paged, n_layers, kv_dim, 37);
            let q = rng.normal_vec(shape.n_heads * shape.head_dim, 1.0);
            let mut flat_scores = vec![0f32; max_seq];
            let mut scores = vec![0f32; shape.scores_len(max_seq)];
            let mut scratch = AttnScratch::new();
            let mut a = vec![0f32; q.len()];
            let mut b = vec![0f32; q.len()];
            let mut c = vec![0f32; q.len()];
            for upto in [1usize, page_size.min(37), 17, 36, 37] {
                for layer in 0..n_layers {
                    attend_flat(&cache, layer, &shape, &q, upto, scale, &mut flat_scores, &mut a);
                    attend(
                        &cache, layer, &shape, &q, upto, scale, &mut scratch, &mut scores, &mut b,
                    );
                    attend(
                        &paged, layer, &shape, &q, upto, scale, &mut scratch, &mut scores, &mut c,
                    );
                    assert_eq!(a, b, "contiguous tiled != flat (page {page_size}, upto {upto})");
                    assert_eq!(a, c, "paged tiled != flat (page {page_size}, upto {upto})");
                }
            }
        }
    }

    #[test]
    fn mqa_and_mha_group_widths() {
        // groups = n_heads (MQA, one KV head) and groups = 1 (MHA).
        for (n_heads, n_kv_heads) in [(4, 1), (4, 4)] {
            let shape = AttnShape { n_heads, n_kv_heads, head_dim: 4 };
            let kv_dim = shape.kv_dim();
            let layout =
                KvLayout { n_layers: 1, kv_dim, page_size: 2, max_seq: 8, dtype: KvDtype::F32 };
            let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
            let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
            let mut cache = KvCache::new(1, 8, kv_dim);
            let mut paged = PagedKv::bind(&mut pool, &mut seq);
            let mut rng = Prng::seeded(11);
            fill_both(&mut rng, &mut cache, &mut paged, 1, kv_dim, 5);
            let q = rng.normal_vec(n_heads * 4, 1.0);
            let mut flat_scores = vec![0f32; 8];
            let mut scores = vec![0f32; shape.scores_len(8)];
            let mut scratch = AttnScratch::new();
            let (mut a, mut b) = (vec![0f32; q.len()], vec![0f32; q.len()]);
            attend_flat(&cache, 0, &shape, &q, 5, 0.5, &mut flat_scores, &mut a);
            attend(&paged, 0, &shape, &q, 5, 0.5, &mut scratch, &mut scores, &mut b);
            assert_eq!(a, b);
        }
    }

    /// Batched prefill must be bit-exact against the per-position walk
    /// over the same store — in every dtype, across page sizes, head
    /// geometries, and chunk splits whose causal boundaries straddle
    /// page boundaries.
    #[test]
    fn attend_batch_bit_exact_vs_per_position_walk() {
        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
            for (n_heads, n_kv_heads, head_dim) in [(4, 2, 8), (4, 1, 4), (3, 3, 4)] {
                let shape = AttnShape { n_heads, n_kv_heads, head_dim };
                let kv_dim = shape.kv_dim();
                let (n_layers, max_seq) = (2, 48);
                let scale = 1.0 / (head_dim as f32).sqrt();
                for page_size in [1usize, 3, 7, 16] {
                    let layout = KvLayout { n_layers, kv_dim, page_size, max_seq, dtype };
                    let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
                    let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
                    let mut paged = PagedKv::bind(&mut pool, &mut seq);
                    let mut rng = Prng::seeded(31 + page_size as u64 + n_heads as u64);
                    // 41 positions: the chunk splits below straddle page
                    // boundaries for every page_size above.
                    let total = 41usize;
                    for pos in 0..total {
                        for layer in 0..n_layers {
                            let k = rng.normal_vec(kv_dim, 1.0);
                            let v = rng.normal_vec(kv_dim, 1.0);
                            paged.write(layer, pos, &k, &v);
                        }
                    }
                    // Chunk the "prompt" as prefill would: [0,13), [13,30), [30,41).
                    for (pos0, m) in [(0usize, 13usize), (13, 17), (30, 11)] {
                        let q = rng.normal_vec(m * n_heads * head_dim, 1.0);
                        let upto_max = pos0 + m;
                        let mut scores_b = vec![0f32; shape.scores_len_batch(m, upto_max)];
                        let mut scores_1 = vec![0f32; shape.scores_len(upto_max)];
                        let mut scratch = AttnScratch::new();
                        let mut out_b = vec![0f32; q.len()];
                        let mut out_1 = vec![0f32; q.len()];
                        for layer in 0..n_layers {
                            attend_batch(
                                &paged, layer, &shape, &q, pos0, m, scale, &mut scratch,
                                &mut scores_b, &mut out_b,
                            );
                            let d = n_heads * head_dim;
                            for b in 0..m {
                                attend(
                                    &paged,
                                    layer,
                                    &shape,
                                    &q[b * d..(b + 1) * d],
                                    pos0 + b + 1,
                                    scale,
                                    &mut scratch,
                                    &mut scores_1,
                                    &mut out_1[b * d..(b + 1) * d],
                                );
                            }
                            assert_eq!(
                                out_b, out_1,
                                "batched != per-position ({dtype:?}, page {page_size}, \
                                 heads {n_heads}/{n_kv_heads}, chunk {pos0}+{m}, layer {layer})"
                            );
                        }
                    }
                }
            }
        }
    }

    /// One batched chunk resolves each tile exactly twice (K pass + V
    /// pass) regardless of chunk length — the point of the score-block
    /// walk; the per-position walk pays ~m× that.
    #[test]
    fn attend_batch_resolves_each_tile_twice_per_chunk() {
        let shape = AttnShape { n_heads: 2, n_kv_heads: 2, head_dim: 4 };
        let kv_dim = shape.kv_dim();
        let layout =
            KvLayout { n_layers: 1, kv_dim, page_size: 4, max_seq: 64, dtype: KvDtype::Int8 };
        let mut pool = BlockPool::new(layout, layout.max_pages_per_seq());
        let mut seq = SeqKv::with_capacity(layout.max_pages_per_seq());
        let mut paged = PagedKv::bind(&mut pool, &mut seq);
        let mut rng = Prng::seeded(5);
        let (pos0, m) = (9usize, 21usize);
        for pos in 0..pos0 + m {
            let k = rng.normal_vec(kv_dim, 1.0);
            let v = rng.normal_vec(kv_dim, 1.0);
            paged.write(0, pos, &k, &v);
        }
        let q = rng.normal_vec(m * shape.n_heads * shape.head_dim, 1.0);
        let upto_max = pos0 + m;
        let mut scores = vec![0f32; shape.scores_len_batch(m, upto_max)];
        let mut scratch = AttnScratch::new();
        let mut out = vec![0f32; q.len()];
        attend_batch(&paged, 0, &shape, &q, pos0, m, 1.0, &mut scratch, &mut scores, &mut out);
        let n_tiles = KvStore::n_tiles(&paged, upto_max) as u64;
        assert_eq!(scratch.tile_resolutions, 2 * n_tiles);
        // Per-position replay of the same chunk: strictly more resolutions.
        scratch.reset_tile_resolutions();
        let mut scores_1 = vec![0f32; shape.scores_len(upto_max)];
        let d = shape.n_heads * shape.head_dim;
        let mut out_1 = vec![0f32; d];
        for b in 0..m {
            attend(
                &paged,
                0,
                &shape,
                &q[b * d..(b + 1) * d],
                pos0 + b + 1,
                1.0,
                &mut scratch,
                &mut scores_1,
                &mut out_1,
            );
        }
        assert!(scratch.tile_resolutions > 2 * n_tiles, "per-position walk should cost more");
    }
}
