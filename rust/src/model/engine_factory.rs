//! Build a [`GemmEngine`] per linear layer from dense weights, for every
//! method in the paper's evaluation. This is how a model is "loaded under"
//! a kernel: `EngineKind::CodeGemm { .. }` quantizes each linear with the
//! additive-codebook pipeline and wraps it in the Psumbook engine.
//!
//! Projections that share one input activation (a layer's Q/K/V, an
//! MLP's gate/up) load through [`EngineKind::build_projection_set`]
//! instead of one `build` per linear: the additive-codebook kinds
//! quantize the **stacked** member rows jointly — one codebook set
//! trained over all members, each sliced back out row-identically, the
//! same post-quantization slicing row shards use — which gives CodeGEMM
//! members the shared codebooks a fused [`GemmGroup`] needs to gather
//! from one Psumbook build per k-tile.

use crate::config::{KernelConfig, ParallelConfig, QuantConfig};
use crate::gemm::{
    CodeGemmEngine, Counters, DenseEngine, DequantEngine, EngineScratch, GemmEngine, GemmGroup,
    GroupMember, LutGemmEngine, UniformGemmEngine,
};
use crate::parallel::{shard, ShardPlan, ShardedEngine, TpLinear};
use crate::quant::calib::TuneLevel;
use crate::quant::{bcq::BcqLinear, uniform::UniformLinear, QuantizedLinear, Quantizer};
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// A set of linears sharing one input activation (a layer's Q/K/V or
/// gate/up), executed either as one fused [`GemmGroup`] call or as
/// independent per-member engines. Built by
/// [`EngineKind::build_projection_set`]; the model's forward pass calls
/// [`ProjectionSet::gemm_set_into`] once per set.
pub enum ProjectionSet {
    /// CodeGEMM members quantized jointly (shared codebooks) fused
    /// around one Psumbook build per k-tile. The group's own `fused`
    /// flag still selects the schedule — off, members run independently
    /// with bit-identical outputs.
    Fused(GemmGroup),
    /// One engine per member, executed back-to-back (non-codebook kinds,
    /// or kinds with nothing to share).
    Independent(Vec<Box<dyn GemmEngine + Send + Sync>>),
}

impl ProjectionSet {
    /// Run every member against `x`, writing member `i`'s batch-major
    /// `n_i × m_batch` product into `outs[i]` (fully overwritten).
    pub fn gemm_set_into(
        &self,
        x: &[f32],
        m_batch: usize,
        outs: &mut [&mut [f32]],
        scratch: &mut EngineScratch,
    ) {
        match self {
            ProjectionSet::Fused(group) => group.gemm_group_into(x, m_batch, outs, scratch),
            ProjectionSet::Independent(engines) => {
                assert_eq!(engines.len(), outs.len(), "one output slice per member");
                for (e, y) in engines.iter().zip(outs.iter_mut()) {
                    e.gemm_into(x, m_batch, y, scratch);
                }
            }
        }
    }

    /// True when calls take the one-shared-build fused path.
    pub fn is_fused(&self) -> bool {
        matches!(self, ProjectionSet::Fused(g) if g.uses_fused())
    }

    pub fn num_members(&self) -> usize {
        match self {
            ProjectionSet::Fused(g) => g.num_members(),
            ProjectionSet::Independent(engines) => engines.len(),
        }
    }

    /// Fold the members' built-in counters (accumulated only by legacy
    /// direct-call paths) into `total`. Fused groups route all work
    /// through the caller's scratch and contribute nothing here.
    pub fn merge_counters(&self, total: &mut Counters) {
        if let ProjectionSet::Independent(engines) = self {
            for e in engines {
                total.merge(e.counters());
            }
        }
    }
}

/// Which kernel/quantization to build engines with.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EngineKind {
    /// Unquantized fp32 matmul (the cuBLAS stand-in / accuracy oracle).
    Dense,
    /// The paper's kernel over additive-codebook weights.
    CodeGemm { cfg: QuantConfig, kernel: KernelConfig, tune: TuneLevel },
    /// Dequantization-based baseline (AQLM-style) on the same format.
    Dequant { cfg: QuantConfig, tune: TuneLevel },
    /// Uniform group quantization (GPTQ/FlexRound class).
    Uniform { bits: usize, group: usize },
    /// BCQ + LUT-GEMM.
    Lut { bits: usize, group: usize },
}

impl EngineKind {
    pub fn codegemm(cfg: QuantConfig) -> EngineKind {
        EngineKind::codegemm_with_kernel(cfg, KernelConfig::default())
    }

    /// [`Self::codegemm`] with explicit kernel-dispatch knobs (the
    /// `serve --kernel-impl/--simd-lanes` path).
    pub fn codegemm_with_kernel(cfg: QuantConfig, kernel: KernelConfig) -> EngineKind {
        EngineKind::CodeGemm { cfg, kernel, tune: TuneLevel::Calibrated }
    }

    /// The kernel selection engines of this kind will dispatch to,
    /// resolved against the host CPU and the `CODEGEMM_KERNEL` override
    /// — without building an engine (`resolve` reads only the config).
    /// `None` for kinds with no CodeGEMM kernel layer.
    pub fn kernel_sel(&self) -> Option<crate::gemm::KernelSel> {
        match self {
            EngineKind::CodeGemm { kernel, .. } => Some(crate::gemm::simd::resolve(kernel)),
            _ => None,
        }
    }

    pub fn label(&self) -> String {
        match self {
            EngineKind::Dense => "fp32".into(),
            EngineKind::CodeGemm { cfg, tune, .. } => format!("CodeGEMM-{}{}", cfg.label(), tune.label()),
            EngineKind::Dequant { cfg, tune } => format!("Dequant-{}{}", cfg.label(), tune.label()),
            EngineKind::Uniform { bits, group } => format!("Uniform-q{bits}g{group}"),
            EngineKind::Lut { bits, group } => format!("LUT-q{bits}g{group}"),
        }
    }

    /// Quantize `w` (row-major `n×k`) and construct the engine.
    /// `h` is an optional per-column calibration importance (diag H).
    pub fn build(&self, w: &[f32], n: usize, k: usize, h: Option<&[f32]>) -> Box<dyn GemmEngine + Send + Sync> {
        match self {
            EngineKind::Dense => Box::new(DenseEngine::new(w.to_vec(), n, k)),
            EngineKind::CodeGemm { cfg, kernel, tune } => {
                let q = Quantizer::new(*cfg)
                    .with_refinement(tune.refine_rounds())
                    .quantize_weighted(w, n, k, h);
                Box::new(CodeGemmEngine::with_kernel(&q, *kernel))
            }
            EngineKind::Dequant { cfg, tune } => {
                let q = Quantizer::new(*cfg)
                    .with_refinement(tune.refine_rounds())
                    .quantize_weighted(w, n, k, h);
                Box::new(DequantEngine::from_quantized(&q))
            }
            EngineKind::Uniform { bits, group } => {
                let q = UniformLinear::quantize(w, n, k, *bits, *group).expect("uniform quantize");
                Box::new(UniformGemmEngine::new(q))
            }
            EngineKind::Lut { bits, group } => {
                let q = BcqLinear::quantize(w, n, k, *bits, *group).expect("bcq quantize");
                Box::new(LutGemmEngine::new(q))
            }
        }
    }

    /// Quantize the additive-codebook formats once over the full matrix
    /// (shared by the sharded builders below, so codebooks are trained on
    /// all rows and shard outputs stay bit-identical to the serial
    /// engine's).
    fn quantize_additive(
        cfg: &QuantConfig,
        tune: &TuneLevel,
        w: &[f32],
        n: usize,
        k: usize,
        h: Option<&[f32]>,
    ) -> QuantizedLinear {
        Quantizer::new(*cfg).with_refinement(tune.refine_rounds()).quantize_weighted(w, n, k, h)
    }

    /// Quantize a projection set's **stacked** rows jointly: one codebook
    /// set trained over every member, so members sliced back out share
    /// codebooks (the fused-group precondition) while each keeps its own
    /// rows' codes and scales byte-identical to its slice.
    fn quantize_stacked(
        cfg: &QuantConfig,
        tune: &TuneLevel,
        parts: &[(&[f32], usize)],
        k: usize,
        hs: &[Option<&[f32]>],
    ) -> QuantizedLinear {
        let n_total: usize = parts.iter().map(|p| p.1).sum();
        let mut stacked = Vec::with_capacity(n_total * k);
        for &(w, n) in parts {
            assert_eq!(w.len(), n * k, "member weight shape mismatch");
            stacked.extend_from_slice(w);
        }
        let h = Self::merge_importances(hs, k);
        Self::quantize_additive(cfg, tune, &stacked, n_total, k, h.as_deref())
    }

    /// Element-wise mean of the members' per-column importances. The
    /// members consume the same input activation, so their diag-H
    /// calibration describes the same `k` columns; averaging keeps every
    /// member's signal without favoring one.
    fn merge_importances(hs: &[Option<&[f32]>], k: usize) -> Option<Vec<f32>> {
        let present: Vec<&[f32]> = hs.iter().flatten().copied().collect();
        if present.is_empty() {
            return None;
        }
        let mut merged = vec![0f32; k];
        for h in &present {
            assert_eq!(h.len(), k, "importance length mismatch");
            for (m, v) in merged.iter_mut().zip(h.iter()) {
                *m += *v;
            }
        }
        let inv = 1.0 / present.len() as f32;
        for m in &mut merged {
            *m *= inv;
        }
        Some(merged)
    }

    /// Build the engines for a set of projections sharing one input
    /// activation: `parts[i] = (w_i, n_i)` (row-major `n_i × k` each),
    /// `hs[i]` the member's optional per-column calibration importance.
    ///
    /// The additive-codebook kinds quantize the stacked rows jointly
    /// ([`Self::quantize_stacked`]) — **unconditionally**, so the
    /// `fused` toggle changes only the schedule and a model is bit-exact
    /// with it on or off (build MACs differ by the member count). This
    /// is a deliberate numerics change vs. per-linear quantization:
    /// codebooks are trained across the set's stacked rows; callers who
    /// need the old per-projection codebooks build each linear through
    /// [`EngineKind::build`] instead. For CodeGEMM the joint codebooks
    /// make the members book-compatible and the set becomes a fused
    /// [`GemmGroup`] — one Psumbook build per k-tile serving every
    /// member. Dequant shares the format (the accuracy tables compare
    /// the two kernels on identical weights) but has no table to share;
    /// it and all other kinds build independent per-member engines.
    ///
    /// `shard_over` row-shards every member across the pool
    /// (column-parallel, exactly like [`EngineKind::build_sharded`]);
    /// under a fused group the shared book then serves the full
    /// shard × member gather matrix.
    pub fn build_projection_set(
        &self,
        parts: &[(&[f32], usize)],
        k: usize,
        hs: &[Option<&[f32]>],
        fused: bool,
        shard_over: Option<(&ParallelConfig, &Arc<ThreadPool>)>,
    ) -> ProjectionSet {
        assert!(!parts.is_empty(), "projection set needs at least one member");
        assert_eq!(parts.len(), hs.len(), "one importance slot per member");
        let member_plan = |n: usize| -> Option<ShardPlan> {
            shard_over.map(|(par, _)| {
                ShardPlan::tiled(n, par.effective_threads(), par.shard_min_rows, self.row_shard_align())
            })
        };
        match self {
            EngineKind::CodeGemm { cfg, kernel, tune } => {
                let q = Self::quantize_stacked(cfg, tune, parts, k, hs);
                let codes = q.codes.unpack(); // once, not per member/shard
                let mut members = Vec::with_capacity(parts.len());
                let mut r0 = 0usize;
                for &(_, n) in parts {
                    let mq = shard::slice_rows_unpacked(&q, &codes, r0, r0 + n);
                    r0 += n;
                    let member = match member_plan(n) {
                        Some(plan) if !plan.is_serial() => {
                            let mcodes = mq.codes.unpack();
                            let shards = plan
                                .shards
                                .iter()
                                .map(|&(s0, s1)| {
                                    CodeGemmEngine::with_kernel(
                                        &shard::slice_rows_unpacked(&mq, &mcodes, s0, s1),
                                        *kernel,
                                    )
                                })
                                .collect();
                            GroupMember::sharded(plan, shards)
                        }
                        _ => GroupMember::serial(CodeGemmEngine::with_kernel(&mq, *kernel)),
                    };
                    members.push(member);
                }
                let pool = shard_over.map(|(_, pool)| Arc::clone(pool));
                let shared = shard_over.map_or(true, |(par, _)| par.shared_psumbook);
                ProjectionSet::Fused(
                    GemmGroup::new(members, pool).with_fused(fused).with_shared_psumbook(shared),
                )
            }
            EngineKind::Dequant { cfg, tune } => {
                let q = Self::quantize_stacked(cfg, tune, parts, k, hs);
                let codes = q.codes.unpack();
                let mut engines: Vec<Box<dyn GemmEngine + Send + Sync>> =
                    Vec::with_capacity(parts.len());
                let mut r0 = 0usize;
                for &(_, n) in parts {
                    let mq = shard::slice_rows_unpacked(&q, &codes, r0, r0 + n);
                    r0 += n;
                    engines.push(match (member_plan(n), shard_over) {
                        (Some(plan), Some((_, pool))) if !plan.is_serial() => {
                            let mcodes = mq.codes.unpack();
                            Box::new(ShardedEngine::from_factory(
                                plan,
                                Arc::clone(pool),
                                |(s0, s1)| {
                                    DequantEngine::from_quantized(&shard::slice_rows_unpacked(
                                        &mq, &mcodes, s0, s1,
                                    ))
                                },
                            ))
                        }
                        _ => Box::new(DequantEngine::from_quantized(&mq)),
                    });
                }
                ProjectionSet::Independent(engines)
            }
            // Dense and the per-row formats: one independent engine per
            // member, sharded exactly as `build_sharded` would.
            _ => ProjectionSet::Independent(
                parts
                    .iter()
                    .zip(hs)
                    .map(|(&(w, n), h)| match (member_plan(n), shard_over) {
                        (Some(plan), Some((par, pool))) => self.build_sharded(
                            w,
                            n,
                            k,
                            *h,
                            &plan,
                            Arc::clone(pool),
                            par.shared_psumbook,
                        ),
                        _ => self.build(w, n, k, *h),
                    })
                    .collect(),
            ),
        }
    }

    /// Build a **row-sharded** (output-dim / column-parallel) engine:
    /// quantize once, slice rows per shard, and fan `gemm` out over
    /// `pool`. Bit-exact vs. the serial engine of the same kind.
    ///
    /// `shared_book` selects the build-once/gather-many schedule for
    /// CodeGEMM shards (one shared Psumbook per k-tile instead of one
    /// private book per shard — see `ParallelConfig::shared_psumbook`);
    /// the other kinds ignore it.
    pub fn build_sharded(
        &self,
        w: &[f32],
        n: usize,
        k: usize,
        h: Option<&[f32]>,
        plan: &ShardPlan,
        pool: Arc<ThreadPool>,
        shared_book: bool,
    ) -> Box<dyn GemmEngine + Send + Sync> {
        if plan.is_serial() {
            return self.build(w, n, k, h);
        }
        assert_eq!(plan.len, n, "plan must partition the output dim");
        match self {
            EngineKind::Dense => Box::new(ShardedEngine::from_factory(plan.clone(), pool, |(r0, r1)| {
                DenseEngine::new(shard::dense_rows(w, k, r0, r1), r1 - r0, k)
            })),
            EngineKind::CodeGemm { cfg, kernel, tune } => {
                let q = Self::quantize_additive(cfg, tune, w, n, k, h);
                let codes = q.codes.unpack(); // once, not per shard
                // Every shard gets the same kernel, so their aligned
                // tile_w values agree and the shared k-tiles line up.
                Box::new(
                    ShardedEngine::from_factory(plan.clone(), pool, |(r0, r1)| {
                        CodeGemmEngine::with_kernel(
                            &shard::slice_rows_unpacked(&q, &codes, r0, r1),
                            *kernel,
                        )
                    })
                    .with_shared_book(shared_book),
                )
            }
            EngineKind::Dequant { cfg, tune } => {
                let q = Self::quantize_additive(cfg, tune, w, n, k, h);
                let codes = q.codes.unpack();
                Box::new(ShardedEngine::from_factory(plan.clone(), pool, |(r0, r1)| {
                    DequantEngine::from_quantized(&shard::slice_rows_unpacked(&q, &codes, r0, r1))
                }))
            }
            // Uniform and BCQ quantization are purely per-row, so
            // quantizing each row slice directly is bit-identical to
            // slicing a full quantization.
            EngineKind::Uniform { bits, group } => {
                Box::new(ShardedEngine::from_factory(plan.clone(), pool, |(r0, r1)| {
                    let ws = shard::dense_rows(w, k, r0, r1);
                    let q = UniformLinear::quantize(&ws, r1 - r0, k, *bits, *group)
                        .expect("uniform quantize");
                    UniformGemmEngine::new(q)
                }))
            }
            EngineKind::Lut { bits, group } => {
                Box::new(ShardedEngine::from_factory(plan.clone(), pool, |(r0, r1)| {
                    let ws = shard::dense_rows(w, k, r0, r1);
                    let q = BcqLinear::quantize(&ws, r1 - r0, k, *bits, *group)
                        .expect("bcq quantize");
                    LutGemmEngine::new(q)
                }))
            }
        }
    }

    /// Row-shard boundary alignment for this kind (use with
    /// [`ShardPlan::tiled`]): the CodeGEMM engine walks rows in `tile_h`
    /// blocks, so row shards aligned to the block height keep the
    /// private per-shard Psumbook build count congruent with the serial
    /// engine's blocking (the shared-book schedule is indifferent, but
    /// congruent plans make private-vs-shared comparisons exact).
    pub fn row_shard_align(&self) -> usize {
        match self {
            EngineKind::CodeGemm { kernel, .. } => kernel.tile_h,
            _ => 1,
        }
    }

    /// Shard-boundary alignment required when partitioning the reduction
    /// dim `k` for this kind: group scales (and code vectors) must never
    /// straddle a shard boundary.
    pub fn k_shard_align(&self, k: usize) -> usize {
        match self {
            EngineKind::Dense => 1,
            EngineKind::CodeGemm { cfg, .. } | EngineKind::Dequant { cfg, .. } => {
                cfg.g.map(|g| g.min(k)).unwrap_or(cfg.v)
            }
            EngineKind::Uniform { group, .. } | EngineKind::Lut { group, .. } => {
                (*group).min(k).max(1)
            }
        }
    }

    /// Build a **row-parallel** (reduction-dim) engine: each shard holds
    /// the full output height over a column range of the weights; partial
    /// products combine via the deterministic ordered all-reduce.
    /// Deterministic, but not bit-identical to serial (the k-sum is
    /// reassociated).
    pub fn build_row_sharded(
        &self,
        w: &[f32],
        n: usize,
        k: usize,
        h: Option<&[f32]>,
        plan: &ShardPlan,
        pool: Arc<ThreadPool>,
    ) -> Box<dyn GemmEngine + Send + Sync> {
        if plan.is_serial() {
            return self.build(w, n, k, h);
        }
        assert_eq!(plan.len, k, "plan must partition the reduction dim");
        let engines: Vec<Box<dyn GemmEngine + Send + Sync>> = match self {
            // Additive-codebook formats: quantize once, column-slice the
            // quantized layer (same codebooks in every shard).
            EngineKind::CodeGemm { cfg, kernel, tune } => {
                let q = Self::quantize_additive(cfg, tune, w, n, k, h);
                let codes = q.codes.unpack(); // once, not per shard
                plan.shards
                    .iter()
                    .map(|&(c0, c1)| {
                        Box::new(CodeGemmEngine::with_kernel(
                            &shard::slice_cols_unpacked(&q, &codes, c0, c1),
                            *kernel,
                        )) as Box<dyn GemmEngine + Send + Sync>
                    })
                    .collect()
            }
            EngineKind::Dequant { cfg, tune } => {
                let q = Self::quantize_additive(cfg, tune, w, n, k, h);
                let codes = q.codes.unpack();
                plan.shards
                    .iter()
                    .map(|&(c0, c1)| {
                        Box::new(DequantEngine::from_quantized(&shard::slice_cols_unpacked(
                            &q, &codes, c0, c1,
                        )))
                            as Box<dyn GemmEngine + Send + Sync>
                    })
                    .collect()
            }
            // Per-row/per-group formats: quantizing the column slice is
            // identical to slicing (group-aligned boundaries guaranteed by
            // `k_shard_align`).
            _ => plan
                .shards
                .iter()
                .map(|&(c0, c1)| {
                    let ws = shard::dense_cols(w, k, c0, c1);
                    let hs = h.map(|h| h[c0..c1].to_vec());
                    self.build(&ws, n, c1 - c0, hs.as_deref())
                })
                .collect(),
        };
        Box::new(TpLinear::row(plan.clone(), engines, pool))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::stats;

    #[test]
    fn every_kind_builds_and_runs() {
        let (n, k) = (32, 64);
        let w = Prng::seeded(1).normal_vec(n * k, 0.05);
        let x = Prng::seeded(2).normal_vec(k, 1.0);
        let y_ref = {
            let mut e = DenseEngine::new(w.clone(), n, k);
            use crate::gemm::GemmEngine;
            e.gemv(&x)
        };
        for kind in [
            EngineKind::Dense,
            EngineKind::codegemm(QuantConfig::new(4, 1, 8, 32).unwrap()),
            EngineKind::Dequant { cfg: QuantConfig::new(4, 1, 8, 32).unwrap(), tune: TuneLevel::None },
            EngineKind::Uniform { bits: 4, group: 32 },
            EngineKind::Lut { bits: 4, group: 32 },
        ] {
            let mut e = kind.build(&w, n, k, None);
            let y = e.gemv(&x);
            assert_eq!(y.len(), n, "{}", kind.label());
            let rel = stats::rel_l2(&y, &y_ref);
            assert!(rel < 0.6, "{}: rel {rel}", kind.label());
        }
    }

    #[test]
    fn build_sharded_is_bit_exact_for_every_kind() {
        let (n, k) = (48, 64);
        let w = Prng::seeded(5).normal_vec(n * k, 0.05);
        let x = Prng::seeded(6).normal_vec(k * 2, 1.0);
        let pool = Arc::new(crate::util::threadpool::ThreadPool::new(3));
        for kind in [
            EngineKind::Dense,
            EngineKind::codegemm(QuantConfig::new(4, 1, 6, 32).unwrap()),
            EngineKind::Dequant { cfg: QuantConfig::new(4, 1, 6, 32).unwrap(), tune: TuneLevel::None },
            EngineKind::Uniform { bits: 4, group: 32 },
            EngineKind::Lut { bits: 3, group: 32 },
        ] {
            let mut serial = kind.build(&w, n, k, None);
            let plan = ShardPlan::new(n, 3, 8, 1);
            // Both Psumbook schedules must be bit-identical to serial:
            // sharding happens after (or commutes with) quantization, and
            // a shared book holds the same entries as private ones.
            for shared in [true, false] {
                let mut sharded = kind.build_sharded(&w, n, k, None, &plan, Arc::clone(&pool), shared);
                assert_eq!(serial.gemm(&x, 2), sharded.gemm(&x, 2), "{} shared={shared}", kind.label());
            }
        }
    }

    #[test]
    fn build_row_sharded_matches_serial_closely() {
        let (n, k) = (24, 128);
        let w = Prng::seeded(7).normal_vec(n * k, 0.05);
        let x = Prng::seeded(8).normal_vec(k, 1.0);
        let pool = Arc::new(crate::util::threadpool::ThreadPool::new(3));
        for kind in [
            EngineKind::Dense,
            EngineKind::codegemm(QuantConfig::new(4, 1, 6, 32).unwrap()),
            EngineKind::Uniform { bits: 4, group: 32 },
            EngineKind::Lut { bits: 3, group: 32 },
        ] {
            let mut serial = kind.build(&w, n, k, None);
            let plan = ShardPlan::new(k, 3, 16, kind.k_shard_align(k));
            let mut sharded = kind.build_row_sharded(&w, n, k, None, &plan, Arc::clone(&pool));
            let (ys, yp) = (serial.gemv(&x), sharded.gemv(&x));
            // k-split reassociates the reduction: equal up to float noise.
            let rel = stats::rel_l2(&yp, &ys);
            assert!(rel < 1e-4, "{}: rel {rel}", kind.label());
        }
    }

    #[test]
    fn projection_set_fuses_codegemm_and_stays_independent_elsewhere() {
        let (n1, n2, k) = (24usize, 16usize, 64usize);
        let w1 = Prng::seeded(21).normal_vec(n1 * k, 0.05);
        let w2 = Prng::seeded(22).normal_vec(n2 * k, 0.05);
        let x = Prng::seeded(23).normal_vec(k * 2, 1.0);
        let parts: [(&[f32], usize); 2] = [(&w1, n1), (&w2, n2)];
        let hs = [None, None];

        let run = |set: &super::ProjectionSet| {
            let mut y1 = vec![f32::NAN; n1 * 2];
            let mut y2 = vec![f32::NAN; n2 * 2];
            let mut scratch = crate::gemm::EngineScratch::new();
            set.gemm_set_into(&x, 2, &mut [&mut y1[..], &mut y2[..]], &mut scratch);
            (y1, y2, scratch.counters)
        };

        // CodeGEMM: fused group; toggling the schedule off is bit-exact
        // (same joint quantization) but pays one build per member.
        let kind = EngineKind::codegemm(QuantConfig::new(4, 1, 6, 32).unwrap());
        let fused = kind.build_projection_set(&parts, k, &hs, true, None);
        let unfused = kind.build_projection_set(&parts, k, &hs, false, None);
        assert!(fused.is_fused());
        assert!(!unfused.is_fused());
        assert_eq!(fused.num_members(), 2);
        let (f1, f2, cf) = run(&fused);
        let (u1, u2, cu) = run(&unfused);
        assert_eq!(f1, u1);
        assert_eq!(f2, u2);
        assert_eq!(cu.build_ops, 2 * cf.build_ops, "2-member group builds once");
        assert_eq!(cf.group_fanout, 2);

        // Dense: independent members, each exactly the standalone engine.
        let dense_set = EngineKind::Dense.build_projection_set(&parts, k, &hs, true, None);
        assert!(!dense_set.is_fused());
        let (d1, d2, _) = run(&dense_set);
        assert_eq!(d1, DenseEngine::new(w1.clone(), n1, k).gemm(&x, 2));
        assert_eq!(d2, DenseEngine::new(w2.clone(), n2, k).gemm(&x, 2));
    }

    #[test]
    fn sharded_projection_set_matches_serial_set_bit_exactly() {
        let (n1, n2, k) = (32usize, 16usize, 64usize);
        let w1 = Prng::seeded(31).normal_vec(n1 * k, 0.05);
        let w2 = Prng::seeded(32).normal_vec(n2 * k, 0.05);
        let x = Prng::seeded(33).normal_vec(k, 1.0);
        let parts: [(&[f32], usize); 2] = [(&w1, n1), (&w2, n2)];
        let hs = [None, None];
        let kind = EngineKind::codegemm(QuantConfig::new(4, 1, 6, 32).unwrap());
        let par = crate::config::ParallelConfig {
            num_threads: 3,
            shard_min_rows: 8,
            ..Default::default()
        };
        let pool = Arc::new(crate::util::threadpool::ThreadPool::new(3));
        let serial = kind.build_projection_set(&parts, k, &hs, true, None);
        let sharded = kind.build_projection_set(&parts, k, &hs, true, Some((&par, &pool)));
        assert!(sharded.is_fused());
        let run = |set: &super::ProjectionSet| {
            let mut y1 = vec![f32::NAN; n1];
            let mut y2 = vec![f32::NAN; n2];
            let mut scratch = crate::gemm::EngineScratch::new();
            set.gemm_set_into(&x, 1, &mut [&mut y1[..], &mut y2[..]], &mut scratch);
            (y1, y2)
        };
        assert_eq!(run(&serial), run(&sharded), "shard × member gather diverged");
    }

    #[test]
    fn codegemm_and_dequant_agree_on_same_format() {
        let (n, k) = (16, 32);
        let w = Prng::seeded(3).normal_vec(n * k, 0.05);
        let x = Prng::seeded(4).normal_vec(k, 1.0);
        let cfg = QuantConfig::new(4, 2, 6, -1).unwrap();
        let tune = TuneLevel::None;
        let mut a = EngineKind::CodeGemm { cfg, kernel: KernelConfig::default(), tune }.build(&w, n, k, None);
        let mut b = EngineKind::Dequant { cfg, tune }.build(&w, n, k, None);
        let (ya, yb) = (a.gemv(&x), b.gemv(&x));
        assert!(stats::rel_l2(&ya, &yb) < 2e-5);
    }
}
