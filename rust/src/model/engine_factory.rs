//! Build a [`GemmEngine`] per linear layer from dense weights, for every
//! method in the paper's evaluation. This is how a model is "loaded under"
//! a kernel: `EngineKind::CodeGemm { .. }` quantizes each linear with the
//! additive-codebook pipeline and wraps it in the Psumbook engine.

use crate::config::{KernelConfig, QuantConfig};
use crate::gemm::{
    CodeGemmEngine, DenseEngine, DequantEngine, GemmEngine, LutGemmEngine, UniformGemmEngine,
};
use crate::parallel::{shard, ShardPlan, ShardedEngine, TpLinear};
use crate::quant::calib::TuneLevel;
use crate::quant::{bcq::BcqLinear, uniform::UniformLinear, QuantizedLinear, Quantizer};
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Which kernel/quantization to build engines with.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EngineKind {
    /// Unquantized fp32 matmul (the cuBLAS stand-in / accuracy oracle).
    Dense,
    /// The paper's kernel over additive-codebook weights.
    CodeGemm { cfg: QuantConfig, kernel: KernelConfig, tune: TuneLevel },
    /// Dequantization-based baseline (AQLM-style) on the same format.
    Dequant { cfg: QuantConfig, tune: TuneLevel },
    /// Uniform group quantization (GPTQ/FlexRound class).
    Uniform { bits: usize, group: usize },
    /// BCQ + LUT-GEMM.
    Lut { bits: usize, group: usize },
}

impl EngineKind {
    pub fn codegemm(cfg: QuantConfig) -> EngineKind {
        EngineKind::CodeGemm { cfg, kernel: KernelConfig::default(), tune: TuneLevel::Calibrated }
    }

    pub fn label(&self) -> String {
        match self {
            EngineKind::Dense => "fp32".into(),
            EngineKind::CodeGemm { cfg, tune, .. } => format!("CodeGEMM-{}{}", cfg.label(), tune.label()),
            EngineKind::Dequant { cfg, tune } => format!("Dequant-{}{}", cfg.label(), tune.label()),
            EngineKind::Uniform { bits, group } => format!("Uniform-q{bits}g{group}"),
            EngineKind::Lut { bits, group } => format!("LUT-q{bits}g{group}"),
        }
    }

    /// Quantize `w` (row-major `n×k`) and construct the engine.
    /// `h` is an optional per-column calibration importance (diag H).
    pub fn build(&self, w: &[f32], n: usize, k: usize, h: Option<&[f32]>) -> Box<dyn GemmEngine + Send + Sync> {
        match self {
            EngineKind::Dense => Box::new(DenseEngine::new(w.to_vec(), n, k)),
            EngineKind::CodeGemm { cfg, kernel, tune } => {
                let q = Quantizer::new(*cfg)
                    .with_refinement(tune.refine_rounds())
                    .quantize_weighted(w, n, k, h);
                Box::new(CodeGemmEngine::with_kernel(&q, *kernel))
            }
            EngineKind::Dequant { cfg, tune } => {
                let q = Quantizer::new(*cfg)
                    .with_refinement(tune.refine_rounds())
                    .quantize_weighted(w, n, k, h);
                Box::new(DequantEngine::from_quantized(&q))
            }
            EngineKind::Uniform { bits, group } => {
                let q = UniformLinear::quantize(w, n, k, *bits, *group).expect("uniform quantize");
                Box::new(UniformGemmEngine::new(q))
            }
            EngineKind::Lut { bits, group } => {
                let q = BcqLinear::quantize(w, n, k, *bits, *group).expect("bcq quantize");
                Box::new(LutGemmEngine::new(q))
            }
        }
    }

    /// Quantize the additive-codebook formats once over the full matrix
    /// (shared by the sharded builders below, so codebooks are trained on
    /// all rows and shard outputs stay bit-identical to the serial
    /// engine's).
    fn quantize_additive(
        cfg: &QuantConfig,
        tune: &TuneLevel,
        w: &[f32],
        n: usize,
        k: usize,
        h: Option<&[f32]>,
    ) -> QuantizedLinear {
        Quantizer::new(*cfg).with_refinement(tune.refine_rounds()).quantize_weighted(w, n, k, h)
    }

    /// Build a **row-sharded** (output-dim / column-parallel) engine:
    /// quantize once, slice rows per shard, and fan `gemm` out over
    /// `pool`. Bit-exact vs. the serial engine of the same kind.
    ///
    /// `shared_book` selects the build-once/gather-many schedule for
    /// CodeGEMM shards (one shared Psumbook per k-tile instead of one
    /// private book per shard — see `ParallelConfig::shared_psumbook`);
    /// the other kinds ignore it.
    pub fn build_sharded(
        &self,
        w: &[f32],
        n: usize,
        k: usize,
        h: Option<&[f32]>,
        plan: &ShardPlan,
        pool: Arc<ThreadPool>,
        shared_book: bool,
    ) -> Box<dyn GemmEngine + Send + Sync> {
        if plan.is_serial() {
            return self.build(w, n, k, h);
        }
        assert_eq!(plan.len, n, "plan must partition the output dim");
        match self {
            EngineKind::Dense => Box::new(ShardedEngine::from_factory(plan.clone(), pool, |(r0, r1)| {
                DenseEngine::new(shard::dense_rows(w, k, r0, r1), r1 - r0, k)
            })),
            EngineKind::CodeGemm { cfg, kernel, tune } => {
                let q = Self::quantize_additive(cfg, tune, w, n, k, h);
                let codes = q.codes.unpack(); // once, not per shard
                // Every shard gets the same kernel, so their aligned
                // tile_w values agree and the shared k-tiles line up.
                Box::new(
                    ShardedEngine::from_factory(plan.clone(), pool, |(r0, r1)| {
                        CodeGemmEngine::with_kernel(
                            &shard::slice_rows_unpacked(&q, &codes, r0, r1),
                            *kernel,
                        )
                    })
                    .with_shared_book(shared_book),
                )
            }
            EngineKind::Dequant { cfg, tune } => {
                let q = Self::quantize_additive(cfg, tune, w, n, k, h);
                let codes = q.codes.unpack();
                Box::new(ShardedEngine::from_factory(plan.clone(), pool, |(r0, r1)| {
                    DequantEngine::from_quantized(&shard::slice_rows_unpacked(&q, &codes, r0, r1))
                }))
            }
            // Uniform and BCQ quantization are purely per-row, so
            // quantizing each row slice directly is bit-identical to
            // slicing a full quantization.
            EngineKind::Uniform { bits, group } => {
                Box::new(ShardedEngine::from_factory(plan.clone(), pool, |(r0, r1)| {
                    let ws = shard::dense_rows(w, k, r0, r1);
                    let q = UniformLinear::quantize(&ws, r1 - r0, k, *bits, *group)
                        .expect("uniform quantize");
                    UniformGemmEngine::new(q)
                }))
            }
            EngineKind::Lut { bits, group } => {
                Box::new(ShardedEngine::from_factory(plan.clone(), pool, |(r0, r1)| {
                    let ws = shard::dense_rows(w, k, r0, r1);
                    let q = BcqLinear::quantize(&ws, r1 - r0, k, *bits, *group)
                        .expect("bcq quantize");
                    LutGemmEngine::new(q)
                }))
            }
        }
    }

    /// Row-shard boundary alignment for this kind (use with
    /// [`ShardPlan::tiled`]): the CodeGEMM engine walks rows in `tile_h`
    /// blocks, so row shards aligned to the block height keep the
    /// private per-shard Psumbook build count congruent with the serial
    /// engine's blocking (the shared-book schedule is indifferent, but
    /// congruent plans make private-vs-shared comparisons exact).
    pub fn row_shard_align(&self) -> usize {
        match self {
            EngineKind::CodeGemm { kernel, .. } => kernel.tile_h,
            _ => 1,
        }
    }

    /// Shard-boundary alignment required when partitioning the reduction
    /// dim `k` for this kind: group scales (and code vectors) must never
    /// straddle a shard boundary.
    pub fn k_shard_align(&self, k: usize) -> usize {
        match self {
            EngineKind::Dense => 1,
            EngineKind::CodeGemm { cfg, .. } | EngineKind::Dequant { cfg, .. } => {
                cfg.g.map(|g| g.min(k)).unwrap_or(cfg.v)
            }
            EngineKind::Uniform { group, .. } | EngineKind::Lut { group, .. } => {
                (*group).min(k).max(1)
            }
        }
    }

    /// Build a **row-parallel** (reduction-dim) engine: each shard holds
    /// the full output height over a column range of the weights; partial
    /// products combine via the deterministic ordered all-reduce.
    /// Deterministic, but not bit-identical to serial (the k-sum is
    /// reassociated).
    pub fn build_row_sharded(
        &self,
        w: &[f32],
        n: usize,
        k: usize,
        h: Option<&[f32]>,
        plan: &ShardPlan,
        pool: Arc<ThreadPool>,
    ) -> Box<dyn GemmEngine + Send + Sync> {
        if plan.is_serial() {
            return self.build(w, n, k, h);
        }
        assert_eq!(plan.len, k, "plan must partition the reduction dim");
        let engines: Vec<Box<dyn GemmEngine + Send + Sync>> = match self {
            // Additive-codebook formats: quantize once, column-slice the
            // quantized layer (same codebooks in every shard).
            EngineKind::CodeGemm { cfg, kernel, tune } => {
                let q = Self::quantize_additive(cfg, tune, w, n, k, h);
                let codes = q.codes.unpack(); // once, not per shard
                plan.shards
                    .iter()
                    .map(|&(c0, c1)| {
                        Box::new(CodeGemmEngine::with_kernel(
                            &shard::slice_cols_unpacked(&q, &codes, c0, c1),
                            *kernel,
                        )) as Box<dyn GemmEngine + Send + Sync>
                    })
                    .collect()
            }
            EngineKind::Dequant { cfg, tune } => {
                let q = Self::quantize_additive(cfg, tune, w, n, k, h);
                let codes = q.codes.unpack();
                plan.shards
                    .iter()
                    .map(|&(c0, c1)| {
                        Box::new(DequantEngine::from_quantized(&shard::slice_cols_unpacked(
                            &q, &codes, c0, c1,
                        )))
                            as Box<dyn GemmEngine + Send + Sync>
                    })
                    .collect()
            }
            // Per-row/per-group formats: quantizing the column slice is
            // identical to slicing (group-aligned boundaries guaranteed by
            // `k_shard_align`).
            _ => plan
                .shards
                .iter()
                .map(|&(c0, c1)| {
                    let ws = shard::dense_cols(w, k, c0, c1);
                    let hs = h.map(|h| h[c0..c1].to_vec());
                    self.build(&ws, n, c1 - c0, hs.as_deref())
                })
                .collect(),
        };
        Box::new(TpLinear::row(plan.clone(), engines, pool))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::stats;

    #[test]
    fn every_kind_builds_and_runs() {
        let (n, k) = (32, 64);
        let w = Prng::seeded(1).normal_vec(n * k, 0.05);
        let x = Prng::seeded(2).normal_vec(k, 1.0);
        let y_ref = {
            let mut e = DenseEngine::new(w.clone(), n, k);
            use crate::gemm::GemmEngine;
            e.gemv(&x)
        };
        for kind in [
            EngineKind::Dense,
            EngineKind::codegemm(QuantConfig::new(4, 1, 8, 32).unwrap()),
            EngineKind::Dequant { cfg: QuantConfig::new(4, 1, 8, 32).unwrap(), tune: TuneLevel::None },
            EngineKind::Uniform { bits: 4, group: 32 },
            EngineKind::Lut { bits: 4, group: 32 },
        ] {
            let mut e = kind.build(&w, n, k, None);
            let y = e.gemv(&x);
            assert_eq!(y.len(), n, "{}", kind.label());
            let rel = stats::rel_l2(&y, &y_ref);
            assert!(rel < 0.6, "{}: rel {rel}", kind.label());
        }
    }

    #[test]
    fn build_sharded_is_bit_exact_for_every_kind() {
        let (n, k) = (48, 64);
        let w = Prng::seeded(5).normal_vec(n * k, 0.05);
        let x = Prng::seeded(6).normal_vec(k * 2, 1.0);
        let pool = Arc::new(crate::util::threadpool::ThreadPool::new(3));
        for kind in [
            EngineKind::Dense,
            EngineKind::codegemm(QuantConfig::new(4, 1, 6, 32).unwrap()),
            EngineKind::Dequant { cfg: QuantConfig::new(4, 1, 6, 32).unwrap(), tune: TuneLevel::None },
            EngineKind::Uniform { bits: 4, group: 32 },
            EngineKind::Lut { bits: 3, group: 32 },
        ] {
            let mut serial = kind.build(&w, n, k, None);
            let plan = ShardPlan::new(n, 3, 8, 1);
            // Both Psumbook schedules must be bit-identical to serial:
            // sharding happens after (or commutes with) quantization, and
            // a shared book holds the same entries as private ones.
            for shared in [true, false] {
                let mut sharded = kind.build_sharded(&w, n, k, None, &plan, Arc::clone(&pool), shared);
                assert_eq!(serial.gemm(&x, 2), sharded.gemm(&x, 2), "{} shared={shared}", kind.label());
            }
        }
    }

    #[test]
    fn build_row_sharded_matches_serial_closely() {
        let (n, k) = (24, 128);
        let w = Prng::seeded(7).normal_vec(n * k, 0.05);
        let x = Prng::seeded(8).normal_vec(k, 1.0);
        let pool = Arc::new(crate::util::threadpool::ThreadPool::new(3));
        for kind in [
            EngineKind::Dense,
            EngineKind::codegemm(QuantConfig::new(4, 1, 6, 32).unwrap()),
            EngineKind::Uniform { bits: 4, group: 32 },
            EngineKind::Lut { bits: 3, group: 32 },
        ] {
            let mut serial = kind.build(&w, n, k, None);
            let plan = ShardPlan::new(k, 3, 16, kind.k_shard_align(k));
            let mut sharded = kind.build_row_sharded(&w, n, k, None, &plan, Arc::clone(&pool));
            let (ys, yp) = (serial.gemv(&x), sharded.gemv(&x));
            // k-split reassociates the reduction: equal up to float noise.
            let rel = stats::rel_l2(&yp, &ys);
            assert!(rel < 1e-4, "{}: rel {rel}", kind.label());
        }
    }

    #[test]
    fn codegemm_and_dequant_agree_on_same_format() {
        let (n, k) = (16, 32);
        let w = Prng::seeded(3).normal_vec(n * k, 0.05);
        let x = Prng::seeded(4).normal_vec(k, 1.0);
        let cfg = QuantConfig::new(4, 2, 6, -1).unwrap();
        let tune = TuneLevel::None;
        let mut a = EngineKind::CodeGemm { cfg, kernel: KernelConfig::default(), tune }.build(&w, n, k, None);
        let mut b = EngineKind::Dequant { cfg, tune }.build(&w, n, k, None);
        let (ya, yb) = (a.gemv(&x), b.gemv(&x));
        assert!(stats::rel_l2(&ya, &yb) < 2e-5);
    }
}
