//! Build a [`GemmEngine`] per linear layer from dense weights, for every
//! method in the paper's evaluation. This is how a model is "loaded under"
//! a kernel: `EngineKind::CodeGemm { .. }` quantizes each linear with the
//! additive-codebook pipeline and wraps it in the Psumbook engine.

use crate::config::{KernelConfig, QuantConfig};
use crate::gemm::{
    CodeGemmEngine, DenseEngine, DequantEngine, GemmEngine, LutGemmEngine, UniformGemmEngine,
};
use crate::quant::calib::TuneLevel;
use crate::quant::{bcq::BcqLinear, uniform::UniformLinear, Quantizer};

/// Which kernel/quantization to build engines with.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EngineKind {
    /// Unquantized fp32 matmul (the cuBLAS stand-in / accuracy oracle).
    Dense,
    /// The paper's kernel over additive-codebook weights.
    CodeGemm { cfg: QuantConfig, kernel: KernelConfig, tune: TuneLevel },
    /// Dequantization-based baseline (AQLM-style) on the same format.
    Dequant { cfg: QuantConfig, tune: TuneLevel },
    /// Uniform group quantization (GPTQ/FlexRound class).
    Uniform { bits: usize, group: usize },
    /// BCQ + LUT-GEMM.
    Lut { bits: usize, group: usize },
}

impl EngineKind {
    pub fn codegemm(cfg: QuantConfig) -> EngineKind {
        EngineKind::CodeGemm { cfg, kernel: KernelConfig::default(), tune: TuneLevel::Calibrated }
    }

    pub fn label(&self) -> String {
        match self {
            EngineKind::Dense => "fp32".into(),
            EngineKind::CodeGemm { cfg, tune, .. } => format!("CodeGEMM-{}{}", cfg.label(), tune.label()),
            EngineKind::Dequant { cfg, tune } => format!("Dequant-{}{}", cfg.label(), tune.label()),
            EngineKind::Uniform { bits, group } => format!("Uniform-q{bits}g{group}"),
            EngineKind::Lut { bits, group } => format!("LUT-q{bits}g{group}"),
        }
    }

    /// Quantize `w` (row-major `n×k`) and construct the engine.
    /// `h` is an optional per-column calibration importance (diag H).
    pub fn build(&self, w: &[f32], n: usize, k: usize, h: Option<&[f32]>) -> Box<dyn GemmEngine + Send> {
        match self {
            EngineKind::Dense => Box::new(DenseEngine::new(w.to_vec(), n, k)),
            EngineKind::CodeGemm { cfg, kernel, tune } => {
                let q = Quantizer::new(*cfg)
                    .with_refinement(tune.refine_rounds())
                    .quantize_weighted(w, n, k, h);
                Box::new(CodeGemmEngine::with_kernel(&q, *kernel))
            }
            EngineKind::Dequant { cfg, tune } => {
                let q = Quantizer::new(*cfg)
                    .with_refinement(tune.refine_rounds())
                    .quantize_weighted(w, n, k, h);
                Box::new(DequantEngine::from_quantized(&q))
            }
            EngineKind::Uniform { bits, group } => {
                let q = UniformLinear::quantize(w, n, k, *bits, *group).expect("uniform quantize");
                Box::new(UniformGemmEngine::new(q))
            }
            EngineKind::Lut { bits, group } => {
                let q = BcqLinear::quantize(w, n, k, *bits, *group).expect("bcq quantize");
                Box::new(LutGemmEngine::new(q))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::stats;

    #[test]
    fn every_kind_builds_and_runs() {
        let (n, k) = (32, 64);
        let w = Prng::seeded(1).normal_vec(n * k, 0.05);
        let x = Prng::seeded(2).normal_vec(k, 1.0);
        let y_ref = {
            let mut e = DenseEngine::new(w.clone(), n, k);
            use crate::gemm::GemmEngine;
            e.gemv(&x)
        };
        for kind in [
            EngineKind::Dense,
            EngineKind::codegemm(QuantConfig::new(4, 1, 8, 32).unwrap()),
            EngineKind::Dequant { cfg: QuantConfig::new(4, 1, 8, 32).unwrap(), tune: TuneLevel::None },
            EngineKind::Uniform { bits: 4, group: 32 },
            EngineKind::Lut { bits: 4, group: 32 },
        ] {
            let mut e = kind.build(&w, n, k, None);
            let y = e.gemv(&x);
            assert_eq!(y.len(), n, "{}", kind.label());
            let rel = stats::rel_l2(&y, &y_ref);
            assert!(rel < 0.6, "{}: rel {rel}", kind.label());
        }
    }

    #[test]
    fn codegemm_and_dequant_agree_on_same_format() {
        let (n, k) = (16, 32);
        let w = Prng::seeded(3).normal_vec(n * k, 0.05);
        let x = Prng::seeded(4).normal_vec(k, 1.0);
        let cfg = QuantConfig::new(4, 2, 6, -1).unwrap();
        let tune = TuneLevel::None;
        let mut a = EngineKind::CodeGemm { cfg, kernel: KernelConfig::default(), tune }.build(&w, n, k, None);
        let mut b = EngineKind::Dequant { cfg, tune }.build(&w, n, k, None);
        let (ya, yb) = (a.gemv(&x), b.gemv(&x));
        assert!(stats::rel_l2(&ya, &yb) < 2e-5);
    }
}
