//! The `artifacts/manifest.json` contract between `python/compile/aot.py`
//! (producer, build time) and the Rust runtime (consumer, serve time).

use crate::config::{ModelConfig, QuantConfig};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled computation.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// e.g. `decode_b4`.
    pub name: String,
    /// Batch size the computation was lowered for.
    pub batch: usize,
    /// HLO text file, relative to the artifacts dir.
    pub hlo: String,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelConfig,
    pub quant: Option<QuantConfig>,
    /// `fp32` or `codegemm`.
    pub engine: String,
    /// Quantized/packed weights container, relative to the artifacts dir.
    pub weights_file: String,
    /// Tensor names, in the exact order the decode-step HLO expects them
    /// *after* the state inputs (tokens, positions, kv_k, kv_v).
    pub weight_args: Vec<String>,
    pub artifacts: Vec<ArtifactSpec>,
}

/// Number of leading state inputs of every decode-step computation:
/// `tokens i32[B]`, `positions i32[B]`, `kv_k f32[L,B,S,KV]`, `kv_v`.
pub const N_STATE_INPUTS: usize = 4;

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text)?;
        Manifest::from_json(dir, &j)
    }

    pub fn from_json(dir: PathBuf, j: &Json) -> Result<Manifest> {
        let version = j.req_usize("version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let model = ModelConfig::from_json(j.get("model").context("missing model")?)?;
        let quant = match j.get("quant") {
            Some(Json::Null) | None => None,
            Some(q) => Some(QuantConfig::from_json(q)?),
        };
        let weight_args = j
            .req_arr("weight_args")?
            .iter()
            .map(|x| x.as_str().map(str::to_string).context("weight_args entries must be strings"))
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = Vec::new();
        for a in j.req_arr("artifacts")? {
            artifacts.push(ArtifactSpec {
                name: a.req_str("name")?.to_string(),
                batch: a.req_usize("batch")?,
                hlo: a.req_str("hlo")?.to_string(),
            });
        }
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }
        Ok(Manifest {
            dir,
            model,
            quant,
            engine: j.req_str("engine")?.to_string(),
            weights_file: j.req_str("weights_file")?.to_string(),
            weight_args,
            artifacts,
        })
    }

    /// Artifact for an exact batch size.
    pub fn artifact_for_batch(&self, batch: usize) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.batch == batch)
    }

    /// Smallest compiled batch ≥ `want` (or the largest available).
    pub fn bucket_for(&self, want: usize) -> &ArtifactSpec {
        self.artifacts
            .iter()
            .filter(|a| a.batch >= want)
            .min_by_key(|a| a.batch)
            .unwrap_or_else(|| self.artifacts.iter().max_by_key(|a| a.batch).unwrap())
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(&self.weights_file)
    }

    pub fn hlo_path(&self, a: &ArtifactSpec) -> PathBuf {
        self.dir.join(&a.hlo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
              "version": 1,
              "engine": "codegemm",
              "model": {"name":"tiny-llama","vocab":256,"hidden":128,"n_layers":2,
                        "n_heads":4,"n_kv_heads":2,"ffn":352,"max_seq":128,"rope_theta":10000.0},
              "quant": {"v":4,"m":1,"b":8,"g":128},
              "weights_file": "weights.q.bin",
              "weight_args": ["embedding","final_norm"],
              "artifacts": [
                {"name":"decode_b1","batch":1,"hlo":"decode_b1.hlo.txt"},
                {"name":"decode_b4","batch":4,"hlo":"decode_b4.hlo.txt"}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_resolves() {
        let m = Manifest::from_json(PathBuf::from("/tmp/a"), &sample_json()).unwrap();
        assert_eq!(m.engine, "codegemm");
        assert_eq!(m.quant.unwrap().v, 4);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifact_for_batch(4).unwrap().name, "decode_b4");
        assert_eq!(m.bucket_for(2).batch, 4);
        assert_eq!(m.bucket_for(3).batch, 4);
        assert_eq!(m.bucket_for(9).batch, 4); // clamps to largest
        assert!(m.weights_path().ends_with("weights.q.bin"));
    }

    #[test]
    fn rejects_bad_version() {
        let mut j = sample_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::from(2usize));
        }
        assert!(Manifest::from_json(PathBuf::from("/tmp"), &j).is_err());
    }
}
