//! PJRT runtime: load AOT artifacts (HLO text + packed weights), compile
//! them once on the CPU PJRT client, and run decode steps from the serve
//! hot path. Python is **never** involved here — the HLO was lowered at
//! build time by `python/compile/aot.py`.

use super::manifest::Manifest;
use crate::util::npy::{TensorData, TensorFile};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A compiled decode-step executable plus its batch size.
pub struct CompiledStep {
    pub batch: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The serve-time model runtime.
///
/// # Safety of the `Send` impl
/// The `xla` crate's handles hold `Rc`s to the PJRT client, so the type is
/// not auto-`Send`. Every `Rc` clone lives *inside* this struct (client,
/// executables, weight literals) — `ModelRuntime::load` leaks none — so
/// moving the whole value to another thread moves every reference
/// together and the non-atomic refcounts are never touched concurrently.
/// The runtime must not be shared (`&ModelRuntime` across threads) —
/// which `Send`-without-`Sync` exactly encodes.
pub struct ModelRuntime {
    pub manifest: Manifest,
    /// Kept alive for the executables' lifetime (never read directly).
    #[allow(dead_code)]
    client: xla::PjRtClient,
    steps: BTreeMap<usize, CompiledStep>,
    /// Weight literals in `manifest.weight_args` order, decoded once at
    /// load and passed to `execute` *by reference* (§Perf: no per-step
    /// weight copies; `execute_b` device buffers segfault on the CPU
    /// plugin because PJRT donates input buffers).
    weights: Vec<xla::Literal>,
}

// SAFETY: see the struct docs — all internal `Rc`s move as one unit and
// the type is not `Sync`, so refcounts are never mutated from two threads.
unsafe impl Send for ModelRuntime {}

impl ModelRuntime {
    /// Load every artifact in `dir` and compile it on the CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<ModelRuntime> {
        let manifest = Manifest::load(dir)?;
        ModelRuntime::from_manifest(manifest)
    }

    pub fn from_manifest(manifest: Manifest) -> Result<ModelRuntime> {
        let client = xla::PjRtClient::cpu()?;
        let mut steps = BTreeMap::new();
        for a in &manifest.artifacts {
            let path = manifest.hlo_path(a);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {}", a.name))?;
            steps.insert(a.batch, CompiledStep { batch: a.batch, exe });
        }
        let weights = load_weight_literals(&manifest)?;
        Ok(ModelRuntime { manifest, client, steps, weights })
    }

    /// Compiled batch sizes, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.steps.keys().copied().collect()
    }

    /// Largest compiled batch (the serving bucket).
    pub fn max_batch(&self) -> usize {
        *self.steps.keys().max().expect("at least one artifact")
    }

    /// Run one decode step at the exact compiled batch size `batch`.
    ///
    /// - `tokens`, `positions`: length `batch` (pad idle slots with 0 /
    ///   their current length — padded writes land at positions that are
    ///   overwritten before ever being read, see coordinator docs).
    /// - `kv_k` / `kv_v`: `[n_layers, batch, max_seq, kv_dim]`, updated
    ///   in place with the step's new K/V rows.
    ///
    /// Returns logits `[batch, vocab]`.
    pub fn decode_step(
        &self,
        batch: usize,
        tokens: &[i32],
        positions: &[i32],
        kv_k: &mut Vec<f32>,
        kv_v: &mut Vec<f32>,
    ) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let kv_len = m.n_layers * batch * m.max_seq * m.kv_dim();
        if kv_k.len() != kv_len || kv_v.len() != kv_len {
            bail!("kv buffers must have length {kv_len}, got {}", kv_k.len());
        }
        let dims = [m.n_layers as i64, batch as i64, m.max_seq as i64, m.kv_dim() as i64];
        let mut lk = xla::Literal::vec1(kv_k.as_slice()).reshape(&dims)?;
        let mut lv = xla::Literal::vec1(kv_v.as_slice()).reshape(&dims)?;
        let logits = self.decode_step_lit(batch, tokens, positions, &mut lk, &mut lv)?;
        lk.copy_raw_to(kv_k.as_mut_slice())?;
        lv.copy_raw_to(kv_v.as_mut_slice())?;
        Ok(logits)
    }

    /// Zero-copy variant of [`ModelRuntime::decode_step`]: the KV state
    /// stays inside PJRT literals across steps — the serve hot path never
    /// round-trips the cache through host vectors (§Perf).
    pub fn decode_step_lit(
        &self,
        batch: usize,
        tokens: &[i32],
        positions: &[i32],
        kv_k: &mut xla::Literal,
        kv_v: &mut xla::Literal,
    ) -> Result<Vec<f32>> {
        let step = self
            .steps
            .get(&batch)
            .with_context(|| format!("no compiled artifact for batch {batch} (have {:?})", self.batch_sizes()))?;
        let m = &self.manifest.model;
        if tokens.len() != batch || positions.len() != batch {
            bail!("tokens/positions must have length {batch}");
        }
        let tok = xla::Literal::vec1(tokens);
        let pos = xla::Literal::vec1(positions);
        let mut args: Vec<&xla::Literal> = vec![&tok, &pos, kv_k, kv_v];
        args.extend(self.weights.iter());
        let result = step.exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (logits, new_k, new_v) = result.to_tuple3()?;
        let logits = logits.to_vec::<f32>()?;
        if logits.len() != batch * m.vocab {
            bail!("logits length {} != batch {batch} × vocab {}", logits.len(), m.vocab);
        }
        *kv_k = new_k;
        *kv_v = new_v;
        Ok(logits)
    }

    /// Allocate zeroed KV literals for a compiled batch size (pairs with
    /// [`ModelRuntime::decode_step_lit`]).
    pub fn new_kv_literals(&self, batch: usize) -> Result<(xla::Literal, xla::Literal)> {
        let m = &self.manifest.model;
        let dims = [m.n_layers as i64, batch as i64, m.max_seq as i64, m.kv_dim() as i64];
        let len = m.n_layers * batch * m.max_seq * m.kv_dim();
        let zeros = vec![0f32; len];
        Ok((
            xla::Literal::vec1(zeros.as_slice()).reshape(&dims)?,
            xla::Literal::vec1(zeros.as_slice()).reshape(&dims)?,
        ))
    }

    /// Allocate zeroed KV buffers for a compiled batch size.
    pub fn new_kv(&self, batch: usize) -> (Vec<f32>, Vec<f32>) {
        let m = &self.manifest.model;
        let len = m.n_layers * batch * m.max_seq * m.kv_dim();
        (vec![0f32; len], vec![0f32; len])
    }

    /// Zero one slot's KV lanes (used when a batch slot is recycled; not
    /// strictly required for correctness — prefill overwrites positions
    /// before they are read — but keeps state inspection sane).
    pub fn clear_slot(&self, kv_k: &mut [f32], kv_v: &mut [f32], batch: usize, slot: usize) {
        let m = &self.manifest.model;
        let per_slot = m.max_seq * m.kv_dim();
        for l in 0..m.n_layers {
            let base = (l * batch + slot) * per_slot;
            kv_k[base..base + per_slot].fill(0.0);
            kv_v[base..base + per_slot].fill(0.0);
        }
    }
}

/// Convert the packed-weights TensorFile into PJRT literals in
/// `weight_args` order.
fn load_weight_literals(manifest: &Manifest) -> Result<Vec<xla::Literal>> {
    let tf = TensorFile::load(manifest.weights_path())
        .with_context(|| format!("loading {}", manifest.weights_path().display()))?;
    let mut out = Vec::with_capacity(manifest.weight_args.len());
    for name in &manifest.weight_args {
        let t = tf.get(name).with_context(|| format!("weights file missing tensor {name}"))?;
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        let lit = match &t.data {
            TensorData::F32(v) => xla::Literal::vec1(v.as_slice()).reshape(&dims)?,
            TensorData::I32(v) => xla::Literal::vec1(v.as_slice()).reshape(&dims)?,
            TensorData::U8(v) => {
                // Codes are shipped as u8 and widened to i32 for gathers.
                let widened: Vec<i32> = v.iter().map(|&x| x as i32).collect();
                xla::Literal::vec1(widened.as_slice()).reshape(&dims)?
            }
            TensorData::U16(v) => {
                // f16 payloads (scales/codebooks) arrive as raw u16 bits;
                // widen through f32 for the runtime.
                let widened: Vec<f32> =
                    v.iter().map(|&bits| crate::util::f16::f16_bits_to_f32(bits)).collect();
                xla::Literal::vec1(widened.as_slice()).reshape(&dims)?
            }
        };
        out.push(lit);
    }
    Ok(out)
}

/// Smoke-level self test of the PJRT bridge that does not require the
/// python-built artifacts: build `f(x) = 2x + 1` with the XlaBuilder,
/// compile on the CPU client, execute, check numbers. Exposed as a
/// function so the CLI's `doctor` subcommand can run it too.
pub fn pjrt_self_test() -> Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let builder = xla::XlaBuilder::new("self_test");
    let x = builder.parameter_s(0, &xla::Shape::array::<f32>(vec![4]), "x")?;
    let y = x.add_(&x)?.sqrt()?;
    let comp = y.build()?;
    let exe = client.compile(&comp)?;
    let input = xla::Literal::vec1(&[2f32, 8.0, 18.0, 32.0]);
    let out = exe.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
    let vals = out.to_vec::<f32>()?;
    if vals != vec![2f32, 4.0, 6.0, 8.0] {
        bail!("PJRT self-test mismatch: {vals:?}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pjrt_cpu_client_works() {
        pjrt_self_test().unwrap();
    }

    #[test]
    fn missing_artifacts_dir_is_a_clear_error() {
        let msg = match ModelRuntime::load("/nonexistent-artifacts") {
            Ok(_) => panic!("load should fail"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("make artifacts"), "error should point at make artifacts: {msg}");
    }
}
