//! Serve-time runtime: load AOT artifacts (HLO text lowered once by
//! `python/compile/aot.py`) and execute them through the PJRT C API via
//! the `xla` crate. Python never runs on the request path — after
//! `make artifacts` the rust binary is self-contained.

pub mod engine;
pub mod manifest;

pub use engine::{pjrt_self_test, ModelRuntime};
pub use manifest::{ArtifactSpec, Manifest, N_STATE_INPUTS};
