//! Synthetic evaluation corpus (DESIGN.md substitution for WikiText-2).
//!
//! A byte-level Markov source with Zipf-weighted transitions: structured
//! enough that a trained (or analytically constructed) model beats the
//! uniform baseline by a wide margin, and fully deterministic given the
//! seed — the accuracy axes of Tables 4/5 and Figure 4(b) measure how
//! quantization degrades a model of *this* source.

use crate::util::prng::Prng;

/// A generated corpus plus its true source statistics.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub vocab: usize,
    pub tokens: Vec<usize>,
    /// True transition log-probabilities, `vocab × vocab` row-major
    /// (`log P(next | cur)`).
    pub log_probs: Vec<f32>,
    pub seed: u64,
}

/// Parameters of the synthetic source.
#[derive(Clone, Copy, Debug)]
pub struct CorpusSpec {
    pub vocab: usize,
    /// Number of plausible successors per symbol (sparsity of the chain).
    pub branching: usize,
    /// Zipf exponent over successor ranks (higher = more deterministic).
    pub zipf_s: f64,
    pub len: usize,
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec { vocab: 256, branching: 8, zipf_s: 1.2, len: 16_384, seed: 0xC0DE }
    }
}

impl Corpus {
    /// Build the Markov source and sample `spec.len` tokens from it.
    pub fn synthesize(spec: CorpusSpec) -> Corpus {
        let v = spec.vocab;
        let mut rng = Prng::seeded(spec.seed);
        // Zipf weights over the branching ranks.
        let weights: Vec<f64> = (0..spec.branching)
            .map(|r| 1.0 / ((r + 1) as f64).powf(spec.zipf_s))
            .collect();
        let wsum: f64 = weights.iter().sum();
        // Successor sets: each symbol transitions to `branching` distinct
        // symbols with Zipf mass (plus epsilon smoothing over the rest so
        // log-probs stay finite).
        let eps = 1e-4f64;
        let mut log_probs = vec![(eps / v as f64).ln() as f32; v * v];
        let mut successors = vec![0usize; v * spec.branching];
        for cur in 0..v {
            let mut pool: Vec<usize> = (0..v).collect();
            rng.shuffle(&mut pool);
            for (rank, &nxt) in pool.iter().take(spec.branching).enumerate() {
                successors[cur * spec.branching + rank] = nxt;
                let p = (1.0 - eps) * weights[rank] / wsum + eps / v as f64;
                log_probs[cur * v + nxt] = p.ln() as f32;
            }
        }
        // Sample the chain.
        let mut tokens = Vec::with_capacity(spec.len);
        let mut cur = rng.index(v);
        for _ in 0..spec.len {
            tokens.push(cur);
            let r = rng.uniform();
            cur = if r < eps {
                rng.index(v)
            } else {
                let rank = rng.weighted_index(&weights);
                successors[cur * spec.branching + rank]
            };
        }
        Corpus { vocab: v, tokens, log_probs, seed: spec.seed }
    }

    /// Entropy rate of the source in nats/token (expected NLL of the true
    /// model — the perplexity floor no model can beat in expectation).
    pub fn entropy_rate(&self) -> f64 {
        // Empirical: average -log P(next|cur) along the sampled chain.
        let mut acc = 0f64;
        for w in self.tokens.windows(2) {
            acc -= self.log_probs[w[0] * self.vocab + w[1]] as f64;
        }
        acc / (self.tokens.len() - 1) as f64
    }

    /// Split into (train, held-out) halves.
    pub fn split(&self) -> (&[usize], &[usize]) {
        let mid = self.tokens.len() / 2;
        (&self.tokens[..mid], &self.tokens[mid..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Corpus::synthesize(CorpusSpec::default());
        let b = Corpus::synthesize(CorpusSpec::default());
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn entropy_far_below_uniform() {
        let c = Corpus::synthesize(CorpusSpec::default());
        let uniform = (c.vocab as f64).ln(); // 5.545 for 256
        let h = c.entropy_rate();
        assert!(h < 0.5 * uniform, "entropy {h} vs uniform {uniform}");
        assert!(h > 0.1, "chain should not be fully deterministic: {h}");
    }

    #[test]
    fn tokens_in_range_and_log_probs_normalized() {
        let c = Corpus::synthesize(CorpusSpec { vocab: 64, len: 2000, ..Default::default() });
        assert!(c.tokens.iter().all(|&t| t < 64));
        for cur in 0..64 {
            let z: f64 = (0..64).map(|n| (c.log_probs[cur * 64 + n] as f64).exp()).sum();
            assert!((z - 1.0).abs() < 1e-3, "row {cur} sums to {z}");
        }
    }

    #[test]
    fn higher_zipf_means_lower_entropy() {
        let mk = |s: f64| {
            Corpus::synthesize(CorpusSpec { zipf_s: s, ..Default::default() }).entropy_rate()
        };
        assert!(mk(2.0) < mk(0.8));
    }
}
