//! Accuracy sweeps: load one set of trained weights under many quantized
//! engines and measure perplexity / task accuracy for each — the engine
//! behind Figure 4(b), the accuracy columns of Tables 4/5, and Figure 5's
//! accuracy axis.

use super::corpus::Corpus;
use super::perplexity::{perplexity, top1_accuracy, top_k_accuracy};
use crate::config::QuantConfig;
use crate::model::{EngineKind, LlamaModel, ModelWeights};
use crate::quant::calib::CalibStats;
use crate::quant::footprint::bits_per_weight;
use crate::util::threadpool::ThreadPool;

/// One accuracy measurement.
#[derive(Clone, Debug)]
pub struct AccuracyRow {
    pub label: String,
    /// Average bits per weight of the linear layers (Eq. 1; 32 for fp32).
    pub bits: f64,
    pub ppl: f64,
    pub top1: f64,
    pub top5: f64,
}

impl AccuracyRow {
    /// Stand-in "Avg." column: mean of the task accuracies.
    pub fn avg(&self) -> f64 {
        0.5 * (self.top1 + self.top5)
    }
}

/// Per-column activation importances for each linear, gathered by running
/// the fp32 model over a calibration stream (the AQLM-style calibration
/// substitution — see DESIGN.md).
pub fn calibrate(weights: &ModelWeights, corpus: &Corpus, n_tokens: usize) -> Vec<Vec<f32>> {
    // Run the dense model and observe per-linear input columns. We proxy
    // the full per-linear hook with layer-input statistics: the hidden
    // state entering each block feeds wq/wk/wv and (post-norm) the MLP;
    // the dominant effect — activation outliers along hidden columns — is
    // captured. lm_head uses the final hidden stats.
    let mut m = LlamaModel::load(weights, EngineKind::Dense, None);
    let d = weights.cfg.hidden;
    let mut stats = CalibStats::new(d);
    let mut cache = m.new_cache();
    let toks: Vec<usize> = corpus.tokens.iter().take(n_tokens.min(weights.cfg.max_seq)).copied().collect();
    for (pos, &t) in toks.iter().enumerate() {
        let _ = m.forward(t, pos, &mut cache);
        stats.observe(&weights.embedding[t * d..(t + 1) * d]);
    }
    let h = stats.importance();
    let mut out = Vec::new();
    for _ in 0..weights.cfg.n_layers {
        for name in ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"] {
            let len = match name {
                "w_down" => weights.cfg.ffn,
                _ => d,
            };
            // Hidden-fed linears share h; ffn-fed (w_down) uses uniform.
            if len == d {
                out.push(h.clone());
            } else {
                out.push(vec![1.0; len]);
            }
        }
    }
    out.push(h); // lm_head
    out
}

/// Measure one engine kind on held-out data.
pub fn measure(
    weights: &ModelWeights,
    kind: EngineKind,
    calib: Option<&[Vec<f32>]>,
    held_out: &[usize],
    max_tokens: usize,
) -> AccuracyRow {
    let mut m = LlamaModel::load(weights, kind, calib);
    let (n, k) = (weights.cfg.hidden, weights.cfg.hidden);
    let bits = match kind {
        EngineKind::Dense => 32.0,
        EngineKind::CodeGemm { cfg, .. } | EngineKind::Dequant { cfg, .. } => {
            bits_per_weight(&cfg, n, k).total
        }
        EngineKind::Uniform { bits, group } | EngineKind::Lut { bits, group } => {
            bits as f64 + 16.0 / group as f64
        }
    };
    AccuracyRow {
        label: kind.label(),
        bits,
        ppl: perplexity(&mut m, held_out, max_tokens),
        top1: top1_accuracy(&mut m, held_out, max_tokens),
        top5: top_k_accuracy(&mut m, held_out, 5, max_tokens),
    }
}

/// Figure 4(b): sweep (v, m, b, g) configurations at similar bit budgets
/// and report (q̄, ppl) points. Runs configs in parallel.
pub fn fig4b_sweep(
    weights: &ModelWeights,
    configs: &[QuantConfig],
    calib: Option<Vec<Vec<f32>>>,
    held_out: &[usize],
    max_tokens: usize,
) -> Vec<AccuracyRow> {
    let pool = ThreadPool::default_size();
    let items: Vec<(QuantConfig, Option<Vec<Vec<f32>>>, Vec<usize>, ModelWeights)> = configs
        .iter()
        .map(|c| (*c, calib.clone(), held_out.to_vec(), weights.clone()))
        .collect();
    pool.parallel_map(items, move |(cfg, calib, held, w)| {
        measure(&w, EngineKind::codegemm(cfg), calib.as_deref(), &held, max_tokens)
    })
}

/// The paper's Figure 4(b) configuration grid (Table 1 ∪ g-sweep).
pub fn fig4b_configs() -> Vec<QuantConfig> {
    let mut out = Vec::new();
    for (v, m, b, g) in [
        // Table 1 rows (≈2-bit budget).
        (4, 1, 8, -1i64),
        (8, 2, 8, -1),
        (16, 4, 8, -1),
        (8, 1, 8, 16),
        (16, 3, 8, 32),
        // g-sweep at the headline configs.
        (4, 1, 8, 128),
        (4, 1, 8, 32),
        (8, 2, 8, 128),
        (8, 2, 8, 32),
        // Higher-bit references.
        (4, 2, 8, 128),
        (8, 4, 8, 128),
    ] {
        out.push(QuantConfig::new(v, m, b, g).unwrap());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::eval::corpus::CorpusSpec;
    use crate::quant::calib::TuneLevel;

    fn setup() -> (ModelWeights, Corpus) {
        let corpus = Corpus::synthesize(CorpusSpec { vocab: 64, len: 1600, ..Default::default() });
        let w = ModelWeights::bigram(ModelConfig::tiny(), &corpus.log_probs, 5);
        (w, corpus)
    }

    #[test]
    fn quantization_degrades_ppl_monotonically_in_bits() {
        let (w, corpus) = setup();
        let (_, held) = corpus.split();
        let fp = measure(&w, EngineKind::Dense, None, held, 120);
        let q8 = measure(
            &w,
            EngineKind::codegemm(QuantConfig::new(4, 4, 8, 32).unwrap()),
            None,
            held,
            120,
        );
        let q2 = measure(
            &w,
            EngineKind::codegemm(QuantConfig::new(8, 1, 8, -1).unwrap()),
            None,
            held,
            120,
        );
        assert!(fp.ppl <= q8.ppl * 1.05, "fp {0} <= ~8bit {1}", fp.ppl, q8.ppl);
        assert!(q8.ppl < q2.ppl, "8-bit-class {0} should beat 1-bit-class {1}", q8.ppl, q2.ppl);
    }

    #[test]
    fn pv_tuning_does_not_hurt() {
        let (w, corpus) = setup();
        let (_, held) = corpus.split();
        let cfg = QuantConfig::new(8, 2, 8, 32).unwrap();
        let base = measure(
            &w,
            EngineKind::CodeGemm { cfg, kernel: Default::default(), tune: TuneLevel::None },
            None,
            held,
            100,
        );
        let tuned = measure(
            &w,
            EngineKind::CodeGemm { cfg, kernel: Default::default(), tune: TuneLevel::PvTuned },
            None,
            held,
            100,
        );
        assert!(tuned.ppl <= base.ppl * 1.10, "tuned {0} vs base {1}", tuned.ppl, base.ppl);
    }

    #[test]
    fn fig4b_configs_cover_bit_range() {
        let cfgs = fig4b_configs();
        assert!(cfgs.len() >= 10);
        let bits: Vec<f64> = cfgs.iter().map(|c| bits_per_weight(c, 4096, 4096).total).collect();
        assert!(bits.iter().cloned().fold(f64::MAX, f64::min) < 2.2);
        assert!(bits.iter().cloned().fold(0.0, f64::max) > 3.0);
    }
}
