//! Perplexity of a model over a token stream (the WikiText-2 stand-in
//! measurement for Figure 4(b) and the accuracy columns of Tables 4/5).

use crate::model::LlamaModel;
use crate::util::stats::log_sum_exp;

/// Per-token negative log-likelihood of `tokens` under the model,
/// evaluated in non-overlapping windows of the model's `max_seq`.
/// Returns (nll_nats_per_token, n_scored_tokens).
pub fn nll(model: &mut LlamaModel, tokens: &[usize], max_tokens: usize) -> (f64, usize) {
    let max_seq = model.cfg.max_seq;
    let mut total = 0f64;
    let mut scored = 0usize;
    'outer: for window in tokens.chunks(max_seq) {
        if window.len() < 2 {
            break;
        }
        let mut cache = model.new_cache();
        for (pos, pair) in window.windows(2).enumerate() {
            let logits = model.forward(pair[0], pos, &mut cache);
            let lse = log_sum_exp(&logits);
            total += (lse - logits[pair[1]]) as f64;
            scored += 1;
            if scored >= max_tokens {
                break 'outer;
            }
        }
    }
    (total / scored.max(1) as f64, scored)
}

/// Perplexity = exp(mean NLL).
pub fn perplexity(model: &mut LlamaModel, tokens: &[usize], max_tokens: usize) -> f64 {
    let (n, _) = nll(model, tokens, max_tokens);
    n.exp()
}

/// Top-1 next-token accuracy (%), the zero-shot-task stand-in.
pub fn top1_accuracy(model: &mut LlamaModel, tokens: &[usize], max_tokens: usize) -> f64 {
    top_k_accuracy(model, tokens, 1, max_tokens)
}

/// Top-k next-token accuracy (%).
pub fn top_k_accuracy(model: &mut LlamaModel, tokens: &[usize], k: usize, max_tokens: usize) -> f64 {
    let max_seq = model.cfg.max_seq;
    let mut hits = 0usize;
    let mut scored = 0usize;
    'outer: for window in tokens.chunks(max_seq) {
        if window.len() < 2 {
            break;
        }
        let mut cache = model.new_cache();
        for (pos, pair) in window.windows(2).enumerate() {
            let logits = model.forward(pair[0], pos, &mut cache);
            let target = logits[pair[1]];
            let better = logits.iter().filter(|&&x| x > target).count();
            if better < k {
                hits += 1;
            }
            scored += 1;
            if scored >= max_tokens {
                break 'outer;
            }
        }
    }
    100.0 * hits as f64 / scored.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::eval::corpus::{Corpus, CorpusSpec};
    use crate::model::{EngineKind, LlamaModel, ModelWeights};

    fn small_spec() -> CorpusSpec {
        CorpusSpec { vocab: 64, len: 1200, ..Default::default() }
    }

    #[test]
    fn random_model_is_near_uniform() {
        let corpus = Corpus::synthesize(small_spec());
        let w = ModelWeights::random(ModelConfig::tiny(), 9);
        let mut m = LlamaModel::load(&w, EngineKind::Dense, None);
        let ppl = perplexity(&mut m, &corpus.tokens, 200);
        // Untrained ≈ vocab-size perplexity (allow wide slack).
        assert!(ppl > 60.0 && ppl < 1200.0, "random-model ppl {ppl}");
    }

    #[test]
    fn bigram_model_beats_uniform_decisively() {
        let corpus = Corpus::synthesize(small_spec());
        let w = ModelWeights::bigram(ModelConfig::tiny(), &corpus.log_probs, 9);
        let mut m = LlamaModel::load(&w, EngineKind::Dense, None);
        let ppl = perplexity(&mut m, &corpus.tokens, 200);
        let floor = corpus.entropy_rate().exp();
        assert!(ppl < 80.0, "bigram-constructed model ppl {ppl} (floor {floor:.2}, uniform 256)");
        assert!(ppl >= floor * 0.7, "cannot beat the source entropy: {ppl} vs floor {floor}");
    }

    #[test]
    fn top1_accuracy_tracks_ppl() {
        let corpus = Corpus::synthesize(small_spec());
        let wb = ModelWeights::bigram(ModelConfig::tiny(), &corpus.log_probs, 9);
        let wr = ModelWeights::random(ModelConfig::tiny(), 9);
        let mut mb = LlamaModel::load(&wb, EngineKind::Dense, None);
        let mut mr = LlamaModel::load(&wr, EngineKind::Dense, None);
        let ab = top1_accuracy(&mut mb, &corpus.tokens, 150);
        let ar = top1_accuracy(&mut mr, &corpus.tokens, 150);
        assert!(ab > ar + 10.0, "bigram acc {ab}% vs random {ar}%");
    }

    #[test]
    fn nll_counts_requested_tokens() {
        let corpus = Corpus::synthesize(small_spec());
        let w = ModelWeights::random(ModelConfig::tiny(), 9);
        let mut m = LlamaModel::load(&w, EngineKind::Dense, None);
        let (_, n) = nll(&mut m, &corpus.tokens, 50);
        assert_eq!(n, 50);
    }
}
