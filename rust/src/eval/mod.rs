//! Accuracy evaluation substrate: synthetic corpus (the WikiText-2 /
//! lm-eval substitution — DESIGN.md §Substitutions), perplexity and
//! next-token task metrics, and the quantization-config sweeps behind
//! Figure 4(b) and the accuracy columns of Tables 4/5.

pub mod corpus;
pub mod perplexity;
pub mod sweep;

pub use corpus::{Corpus, CorpusSpec};
pub use perplexity::{nll, perplexity, top1_accuracy, top_k_accuracy};
pub use sweep::{calibrate, fig4b_configs, fig4b_sweep, measure, AccuracyRow};
