//! Minimal property-based testing framework (offline stand-in for
//! `proptest`). Runs a property over `cases` random inputs drawn from a
//! generator; on failure it attempts greedy shrinking via user-provided
//! simplification and reports the minimal counterexample with the seed.
//!
//! Besides the generic combinators, this module hosts the crate's
//! *reusable engine-test generators*: [`GemmCase`] /[`GemmCaseGen`]
//! produce seeded quantized-layer geometries (shape, quant config,
//! shard count, batch) with helpers that materialize the weights,
//! activations, quantized layer and engines — shared by the
//! `gemm_into`, `parallel` and shared-Psumbook property suites instead
//! of each hand-rolling its own setup.

use crate::config::QuantConfig;
use crate::gemm::CodeGemmEngine;
use crate::quant::{QuantizedLinear, Quantizer};
use crate::util::prng::Prng;

/// A generator of random values for property tests.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Prng) -> T;
    /// Candidate simplifications of a failing value (smaller first).
    fn shrink(&self, _value: &T) -> Vec<T> {
        Vec::new()
    }
}

/// Generator from closures.
pub struct FnGen<T, G: Fn(&mut Prng) -> T, S: Fn(&T) -> Vec<T>> {
    pub gen: G,
    pub shrinker: S,
}

impl<T, G: Fn(&mut Prng) -> T, S: Fn(&T) -> Vec<T>> Gen<T> for FnGen<T, G, S> {
    fn generate(&self, rng: &mut Prng) -> T {
        (self.gen)(rng)
    }
    fn shrink(&self, value: &T) -> Vec<T> {
        (self.shrinker)(value)
    }
}

/// Build a generator from a closure with no shrinking.
pub fn gen_fn<T>(f: impl Fn(&mut Prng) -> T) -> impl Gen<T> {
    FnGen { gen: f, shrinker: |_: &T| Vec::new() }
}

/// Uniform usize in `[lo, hi]` with halving shrink toward `lo`.
pub fn usize_in(lo: usize, hi: usize) -> impl Gen<usize> {
    assert!(lo <= hi);
    FnGen {
        gen: move |rng: &mut Prng| lo + rng.index(hi - lo + 1),
        shrinker: move |v: &usize| {
            let mut c = Vec::new();
            if *v > lo {
                c.push(lo);
                let mid = lo + (*v - lo) / 2;
                if mid != lo && mid != *v {
                    c.push(mid);
                }
                if *v - 1 != lo {
                    c.push(*v - 1);
                }
            }
            c
        },
    }
}

/// Vec of f32 normals with length in `[min_len, max_len]`; shrinks by
/// halving length and zeroing elements.
pub fn f32_vec(min_len: usize, max_len: usize, std: f32) -> impl Gen<Vec<f32>> {
    FnGen {
        gen: move |rng: &mut Prng| {
            let n = min_len + rng.index(max_len - min_len + 1);
            rng.normal_vec(n, std)
        },
        shrinker: move |v: &Vec<f32>| {
            let mut c = Vec::new();
            if v.len() > min_len {
                let half = (v.len() / 2).max(min_len);
                c.push(v[..half].to_vec());
            }
            if v.iter().any(|x| *x != 0.0) {
                c.push(vec![0.0; v.len()]);
            }
            c
        },
    }
}

/// One random quantized-layer GEMM scenario: codebook hyperparameters
/// (`v`, `m`, `b`, `g`), layer shape (`n × k`), a row-shard count, a
/// batch width and the seed that materializes deterministic weights and
/// activations for it. Sampled combinations may be invalid (e.g. `g < v`)
/// — [`GemmCase::quant_config`] returns `None` there and properties
/// treat the case as vacuous.
#[derive(Clone, Copy, Debug)]
pub struct GemmCase {
    pub v: usize,
    pub m: usize,
    pub b: usize,
    pub g: i64,
    pub n: usize,
    pub k: usize,
    pub shards: usize,
    pub mb: usize,
    pub seed: u64,
}

impl GemmCase {
    /// The quant config, when the sampled combination is valid.
    pub fn quant_config(&self) -> Option<QuantConfig> {
        QuantConfig::new(self.v, self.m, self.b, self.g).ok()
    }

    /// Deterministic dense weights for the case (`n × k`, given std).
    pub fn weights(&self, std: f32) -> Vec<f32> {
        Prng::seeded(self.seed).normal_vec(self.n * self.k, std)
    }

    /// Deterministic activations (`k × mb`, batch-major). `salt`
    /// decorrelates multiple streams drawn from the same case.
    pub fn activations(&self, salt: u64) -> Vec<f32> {
        Prng::seeded(self.seed ^ salt).normal_vec(self.k * self.mb, 1.0)
    }

    /// Quantize the case's weights under its config (`None` when the
    /// config is invalid).
    pub fn quantized(&self, std: f32) -> Option<QuantizedLinear> {
        let cfg = self.quant_config()?;
        Some(Quantizer::new(cfg).quantize(&self.weights(std), self.n, self.k))
    }

    /// Serial CodeGEMM engine over the case's quantized layer.
    pub fn codegemm_engine(&self, std: f32) -> Option<CodeGemmEngine> {
        Some(CodeGemmEngine::from_quantized(&self.quantized(std)?))
    }
}

/// Configurable generator of [`GemmCase`]s. Fields are slices of the
/// admissible values per dimension, so suites can pin e.g.
/// `bs: &[1, 2, 4]` or `mbs: &[1, 4, 64]` while sharing the shrinking
/// logic (toward one shard, the first batch width, and the smallest
/// shape).
#[derive(Clone, Copy, Debug)]
pub struct GemmCaseGen {
    pub vs: &'static [usize],
    pub ms: &'static [usize],
    pub bs: &'static [usize],
    pub gs: &'static [i64],
    pub mbs: &'static [usize],
    pub max_shards: usize,
    /// `n` is drawn as `n_unit * {1..=n_steps}`.
    pub n_unit: usize,
    pub n_steps: usize,
    /// `k` is drawn as `k_unit * {1..=k_steps}` (keep `k_unit` a multiple
    /// of every `v` in `vs`).
    pub k_unit: usize,
    pub k_steps: usize,
}

impl Default for GemmCaseGen {
    fn default() -> Self {
        GemmCaseGen {
            vs: &[4, 8],
            ms: &[1, 2],
            bs: &[3, 4, 5, 6],
            gs: &[32, 64, -1],
            mbs: &[1, 2, 3, 4, 5, 6, 7, 8],
            max_shards: 5,
            n_unit: 8,
            n_steps: 8,
            k_unit: 32,
            k_steps: 4,
        }
    }
}

impl Gen<GemmCase> for GemmCaseGen {
    fn generate(&self, rng: &mut Prng) -> GemmCase {
        GemmCase {
            v: self.vs[rng.index(self.vs.len())],
            m: self.ms[rng.index(self.ms.len())],
            b: self.bs[rng.index(self.bs.len())],
            g: self.gs[rng.index(self.gs.len())],
            n: self.n_unit * (1 + rng.index(self.n_steps)),
            k: self.k_unit * (1 + rng.index(self.k_steps)),
            shards: 1 + rng.index(self.max_shards),
            mb: self.mbs[rng.index(self.mbs.len())],
            seed: rng.next_u64(),
        }
    }

    fn shrink(&self, c: &GemmCase) -> Vec<GemmCase> {
        let mut out = Vec::new();
        if c.shards > 1 {
            out.push(GemmCase { shards: 1, ..*c });
        }
        if c.mb != self.mbs[0] {
            out.push(GemmCase { mb: self.mbs[0], ..*c });
        }
        if c.n > self.n_unit {
            out.push(GemmCase { n: self.n_unit, ..*c });
        }
        if c.k > self.k_unit {
            out.push(GemmCase { k: self.k_unit, ..*c });
        }
        out
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult<T> {
    Pass { cases: usize },
    Fail { seed: u64, original: T, minimal: T, message: String },
}

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0DE_6E44, max_shrink_steps: 200 }
    }
}

/// Run `prop` on `cfg.cases` random inputs. `prop` returns `Err(msg)` to
/// signal failure (assert-style helpers below).
pub fn check<T: Clone>(
    cfg: PropConfig,
    gen: &impl Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) -> PropResult<T> {
    let mut rng = Prng::seeded(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // Greedy shrink.
            let mut best = value.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in gen.shrink(&best) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            let _ = case;
            return PropResult::Fail { seed: cfg.seed, original: value, minimal: best, message: best_msg };
        }
    }
    PropResult::Pass { cases: cfg.cases }
}

/// Assert wrapper: panics with a readable report on failure. Use inside
/// `#[test]` functions.
pub fn assert_prop<T: Clone + std::fmt::Debug>(
    name: &str,
    cfg: PropConfig,
    gen: &impl Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    match check(cfg, gen, prop) {
        PropResult::Pass { .. } => {}
        PropResult::Fail { seed, original, minimal, message } => {
            panic!(
                "property '{name}' failed (seed={seed:#x})\n  message: {message}\n  original: {original:?}\n  minimal:  {minimal:?}"
            );
        }
    }
}

/// Property helper: check a boolean with a message.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Property helper: approximate equality.
pub fn ensure_close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let g = usize_in(0, 100);
        match check(PropConfig::default(), &g, |v| ensure(*v <= 100, "range")) {
            PropResult::Pass { cases } => assert_eq!(cases, 64),
            PropResult::Fail { .. } => panic!("should pass"),
        }
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        // Fails for v >= 10; minimal counterexample by our shrinker should
        // be small (close to 10).
        let g = usize_in(0, 1000);
        match check(PropConfig { cases: 200, ..Default::default() }, &g, |v| ensure(*v < 10, "v<10")) {
            PropResult::Fail { minimal, .. } => assert!(minimal >= 10 && minimal <= 20, "minimal={minimal}"),
            PropResult::Pass { .. } => panic!("should fail"),
        }
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = f32_vec(2, 8, 1.0);
        let mut rng = Prng::seeded(1);
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!((2..=8).contains(&v.len()));
        }
    }

    #[test]
    fn gemm_cases_generate_consistent_shapes_and_shrink_smaller() {
        let g = GemmCaseGen::default();
        let mut rng = Prng::seeded(9);
        for i in 0..50 {
            let c = g.generate(&mut rng);
            assert_eq!(c.k % c.v, 0, "k must stay a v multiple");
            assert!(c.n >= 8 && c.mb >= 1 && c.shards >= 1 && c.shards <= 5);
            assert_eq!(c.activations(1).len(), c.k * c.mb);
            assert_eq!(c.weights(0.05).len(), c.n * c.k);
            // Quantization is the expensive part — spot-check a few.
            if i < 2 {
                if let Some(q) = c.quantized(0.05) {
                    assert_eq!((q.n, q.k), (c.n, c.k));
                    assert!(c.codegemm_engine(0.05).is_some());
                }
            }
        }
        let big = GemmCase { v: 4, m: 1, b: 3, g: 32, n: 64, k: 128, shards: 4, mb: 8, seed: 1 };
        let shrunk = g.shrink(&big);
        assert!(!shrunk.is_empty());
        assert!(shrunk.iter().all(|s| s.shards <= big.shards && s.n <= big.n && s.k <= big.k));
    }

    #[test]
    fn ensure_close_tolerance() {
        assert!(ensure_close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-6).is_err());
    }

    #[test]
    #[should_panic(expected = "property 'demo' failed")]
    fn assert_prop_panics_with_report() {
        let g = usize_in(0, 10);
        assert_prop("demo", PropConfig::default(), &g, |v| ensure(*v > 100, "impossible"));
    }
}
