//! IEEE-754 binary16 conversion.
//!
//! The paper stores codebooks, scales, and activations in FP16. The CPU
//! engines compute in f32 but *round every stored value through the f16
//! grid* so quantization error matches what the GPU kernels would see.
//! No `half` crate offline, so the conversions are implemented directly.

/// Convert f32 -> f16 bit pattern (round-to-nearest-even, with proper
/// handling of subnormals, infinities and NaN).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }

    // Unbiased exponent, re-biased for f16.
    let unbiased = exp - 127;
    let f16_exp = unbiased + 15;

    if f16_exp >= 0x1F {
        // Overflow -> infinity
        return sign | 0x7C00;
    }
    if f16_exp <= 0 {
        // Subnormal or underflow to zero.
        if f16_exp < -10 {
            return sign; // rounds to +-0
        }
        // Add implicit leading 1, shift into subnormal position.
        let mant = mant | 0x0080_0000;
        let shift = 14 - f16_exp; // in [14, 24]
        let half = 1u32 << (shift - 1);
        let rounded = mant + (half - 1) + ((mant >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }

    // Normal: round mantissa from 23 to 10 bits (RNE).
    let half = 0x0000_1000u32; // 1 << 12
    let rounded = mant + (half - 1) + ((mant >> 13) & 1);
    if rounded & 0x0080_0000 != 0 {
        // Mantissa overflowed into the exponent.
        let f16_exp = f16_exp + 1;
        if f16_exp >= 0x1F {
            return sign | 0x7C00;
        }
        return sign | ((f16_exp as u16) << 10);
    }
    sign | ((f16_exp as u16) << 10) | (rounded >> 13) as u16
}

/// Convert f16 bit pattern -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;

    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize.
            let mut exp = 127 - 15 + 1;
            let mut mant = mant;
            while mant & 0x0400 == 0 {
                mant <<= 1;
                exp -= 1;
            }
            sign | ((exp as u32) << 23) | ((mant & 0x03FF) << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 value through the f16 grid (the storage precision of
/// codebooks/scales in the paper's format).
#[inline]
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Round a whole slice in place through the f16 grid.
pub fn round_f16_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = round_f16(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_roundtrip() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(round_f16(x), x, "{i} should be exact in f16");
        }
    }

    #[test]
    fn halves_roundtrip() {
        for i in -100..100 {
            let x = i as f32 + 0.5;
            assert_eq!(round_f16(x), x);
        }
    }

    #[test]
    fn overflow_to_inf() {
        assert!(round_f16(70000.0).is_infinite());
        assert!(round_f16(-70000.0).is_infinite());
        assert_eq!(round_f16(65504.0), 65504.0); // f16 max
    }

    #[test]
    fn nan_stays_nan() {
        assert!(round_f16(f32::NAN).is_nan());
    }

    #[test]
    fn subnormals() {
        let tiny = 5.96e-8; // smallest positive f16 subnormal ~5.96e-8
        let r = round_f16(tiny);
        assert!(r > 0.0 && r < 1e-7);
        assert_eq!(round_f16(1e-12), 0.0); // underflow
    }

    #[test]
    fn signed_zero() {
        assert_eq!(round_f16(-0.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(round_f16(0.0).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn relative_error_bounded_for_normals() {
        // f16 has 11 significand bits -> rel err <= 2^-11.
        let mut state = 12345u64;
        for _ in 0..10_000 {
            let r = crate::util::prng::splitmix64(&mut state);
            let x = ((r >> 40) as f32 / (1u64 << 24) as f32 - 0.5) * 100.0;
            if x.abs() < 1e-3 {
                continue;
            }
            let y = round_f16(x);
            assert!(((y - x) / x).abs() <= 1.0 / 2048.0 + 1e-7, "x={x} y={y}");
        }
    }

    #[test]
    fn bit_exact_against_reference_cases() {
        // Spot values cross-checked against numpy float16.
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(0.5), 0x3800);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF);
        assert_eq!(f16_bits_to_f32(0x3555), 0.33325195);
    }
}
