//! ASCII / Markdown table rendering for paper-style output.
//!
//! Every bench target prints its table through this module so the rows can
//! be compared side-by-side with the paper's tables.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            rows: Vec::new(),
        }
    }

    pub fn align(mut self, col: usize, a: Align) -> Table {
        self.aligns[col] = a;
        self
    }

    /// Add a row of pre-formatted cells.
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Add a row from &str cells.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        self.row(cells.iter().map(|s| s.to_string()).collect())
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    fn fmt_cell(cell: &str, width: usize, align: Align) -> String {
        let pad = width.saturating_sub(cell.chars().count());
        match align {
            Align::Left => format!("{cell}{}", " ".repeat(pad)),
            Align::Right => format!("{}{cell}", " ".repeat(pad)),
        }
    }

    /// Render as a box-drawn ASCII table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let sep: String = {
            let mut s = String::from("+");
            for wi in &w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push('|');
        for (i, h) in self.headers.iter().enumerate() {
            out.push_str(&format!(" {} |", Self::fmt_cell(h, w[i], Align::Left)));
        }
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push('|');
            for (i, c) in r.iter().enumerate() {
                out.push_str(&format!(" {} |", Self::fmt_cell(c, w[i], self.aligns[i])));
            }
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as GitHub-flavoured Markdown.
    pub fn render_markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push('|');
        for (i, h) in self.headers.iter().enumerate() {
            out.push_str(&format!(" {} |", Self::fmt_cell(h, w[i], Align::Left)));
        }
        out.push('\n');
        out.push('|');
        for (i, wi) in w.iter().enumerate() {
            let dashes = "-".repeat(*wi);
            match self.aligns[i] {
                Align::Left => out.push_str(&format!(" {dashes} |")),
                Align::Right => out.push_str(&format!(" {dashes}:|")),
            }
        }
        out.push('\n');
        for r in &self.rows {
            out.push('|');
            for (i, c) in r.iter().enumerate() {
                out.push_str(&format!(" {} |", Self::fmt_cell(c, w[i], self.aligns[i])));
            }
            out.push('\n');
        }
        out
    }

    /// Render rows as CSV (headers included) for downstream plotting.
    pub fn render_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for r in &self.rows {
            let esc: Vec<String> = r
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            out.push_str(&esc.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `prec` decimals.
pub fn fnum(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Format microseconds adaptively.
pub fn fus(us: f64) -> String {
    if us >= 10_000.0 {
        format!("{:.1}", us)
    } else if us >= 100.0 {
        format!("{:.2}", us)
    } else {
        format!("{:.2}", us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Title", &["name", "val"]);
        t.row_str(&["alpha", "1.5"]);
        t.row_str(&["b", "22"]);
        t
    }

    #[test]
    fn render_contains_cells_and_title() {
        let s = sample().render();
        assert!(s.contains("Title"));
        assert!(s.contains("alpha"));
        assert!(s.contains("22"));
        assert!(s.starts_with("Title\n+"));
    }

    #[test]
    fn markdown_has_alignment_row() {
        let s = sample().render_markdown();
        assert!(s.contains("|"));
        assert!(s.contains(":-") || s.contains("-:")); // right-aligned marker
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["a"]);
        t.row_str(&["x,y"]);
        assert!(t.render_csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn widths_accommodate_long_rows() {
        let mut t = Table::new("", &["h"]);
        t.row_str(&["a-very-long-cell"]);
        let line = t.render().lines().nth(1).unwrap().to_string();
        assert!(line.len() >= "a-very-long-cell".len());
    }
}
