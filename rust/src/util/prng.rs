//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through SplitMix64 — the same construction used by
//! `rand_xoshiro`, reimplemented here because the offline registry carries
//! no `rand` facade. All stochastic components of the crate (k-means init,
//! synthetic corpora, workload generators, property tests) are driven by
//! this PRNG so every experiment is bit-reproducible from a seed.

/// SplitMix64 step — used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
    /// Cached second normal variate from the Box-Muller pair.
    spare_normal: Option<f64>,
}

impl Prng {
    /// Construct from a 64-bit seed (expanded via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s, spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                // fast path: no bias possible
                return (m >> 64) as u64;
            }
            let threshold = n.wrapping_neg() % n;
            if lo >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with iid normals scaled by `std`.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.normal_f32() * std;
        }
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v, std);
        v
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.index(weights.len());
        }
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork a stream-independent child generator (for parallel workers).
    pub fn fork(&mut self) -> Prng {
        Prng::seeded(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Prng::seeded(42);
        let mut b = Prng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Prng::seeded(1);
        let mut b = Prng::seeded(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Prng::seeded(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Prng::seeded(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Prng::seeded(5);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::seeded(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::seeded(7);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Prng::seeded(8);
        let w = [0.0, 10.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.weighted_index(&w), 1);
        }
    }

    #[test]
    fn fork_streams_diverge() {
        let mut a = Prng::seeded(9);
        let mut child = a.fork();
        assert_ne!(a.next_u64(), child.next_u64());
    }
}
