//! Fixed-size worker thread pool (offline stand-in for `rayon`/`tokio`).
//!
//! Drives the coordinator's request execution, parallel parameter sweeps,
//! and the `parallel::` sharded GEMM engines (which share one pool via
//! `Arc<ThreadPool>` across every linear layer). Scoped `parallel_map`
//! keeps the API simple and safe.
//!
//! Panic behaviour: a panicking job never kills a worker thread (the
//! unwind is caught, so the pool keeps its full width) and never wedges
//! `parallel_map` — the first panic payload is re-thrown at the
//! `parallel_map` caller once all jobs of that call have settled.

use crate::obs::prof;
use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A borrowed job for [`ThreadPool::scope_run`]: may capture references
/// into the caller's stack (`'env`), e.g. disjoint `&mut` sub-slices of
/// one output buffer plus a per-worker scratch.
pub type ScopedJob<'env> = Box<dyn FnOnce() + Send + 'env>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming a shared queue.
///
/// The sender side is wrapped in a `Mutex` so the pool is `Sync` on every
/// supported toolchain (`mpsc::Sender` gained `Sync` only in newer Rust)
/// — sharded engines hold `Arc<ThreadPool>` and must be `Send`.
pub struct ThreadPool {
    tx: Mutex<mpsc::Sender<Msg>>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (minimum 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                thread::Builder::new()
                    .name(format!("codegemm-worker-{i}"))
                    .spawn(move || loop {
                        // The lock guard is dropped before the job runs, so
                        // a panicking job can never poison the receiver.
                        let msg = { rx.lock().expect("job queue lock").recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                // Catch unwinds so a panicking job does not
                                // kill this worker (which would shrink the
                                // pool and wedge later calls). parallel_map
                                // jobs catch their own panics first and
                                // forward the payload to the caller.
                                //
                                // The profiler span is the generic per-job
                                // `job` layer (labelled build/gather spans
                                // nest inside it); with profiling off this
                                // is one relaxed load.
                                let t0 = prof::begin();
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                prof::record_since(prof::Label::Job, 0, t0);
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Mutex::new(tx), handles, size }
    }

    /// Pool sized to available parallelism.
    pub fn default_size() -> ThreadPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    /// Pool sized to `n`, or available parallelism when `n == 0`.
    pub fn with_threads(n: usize) -> ThreadPool {
        if n == 0 {
            ThreadPool::default_size()
        } else {
            ThreadPool::new(n)
        }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    fn send(&self, msg: Msg) {
        self.tx.lock().expect("pool sender lock").send(msg).expect("pool alive");
    }

    /// Fire-and-forget job submission.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.send(Msg::Run(Box::new(job)));
    }

    /// Apply `f` to each item, preserving order, using the pool.
    ///
    /// If `f` panics for any item, the panic is re-thrown on the calling
    /// thread (after the remaining items have settled) instead of
    /// deadlocking — mirroring `std::thread::scope` semantics.
    pub fn parallel_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panic: Option<Box<dyn Any + Send>> = None;
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result");
            match r {
                Ok(v) => out[i] = Some(v),
                // Keep draining so every job of this call settles before
                // the unwind; only the first payload is re-thrown.
                Err(p) => {
                    if panic.is_none() {
                        panic = Some(p);
                    }
                }
            }
        }
        if let Some(p) = panic {
            resume_unwind(p);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    /// Run `jobs` on the pool, blocking until every job has settled — the
    /// scoped-threads pattern (`std::thread::scope` semantics on a
    /// persistent pool). Because this call only returns once all jobs
    /// have completed, the jobs may borrow from the caller's stack; the
    /// sharded GEMM engines use this to hand each worker a sub-slice of
    /// the caller's output buffer and a `&mut` per-worker scratch with no
    /// allocation or `Arc` traffic. If any job panics, the first payload
    /// is re-thrown here after the remaining jobs of this call settle
    /// (workers themselves never die — see `parallel_map`).
    pub fn scope_run<'env>(&self, jobs: Vec<ScopedJob<'env>>) {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        let (rtx, rrx) = mpsc::channel::<thread::Result<()>>();
        for job in jobs {
            // SAFETY: erasing `'env` to `'static` is sound because the
            // receive loop below blocks until every job has reported, so
            // no job — nor anything it borrows — outlives this frame.
            // (`Box<dyn FnOnce + Send + 'a>` has the same layout for any
            // `'a`; only the lifetime bound is erased.)
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            let rtx = rtx.clone();
            self.submit(move || {
                let r = catch_unwind(AssertUnwindSafe(job));
                let _ = rtx.send(r.map(|_| ()));
            });
        }
        drop(rtx);
        let mut panic: Option<Box<dyn Any + Send>> = None;
        for _ in 0..n {
            match rrx.recv().expect("worker result") {
                Ok(()) => {}
                Err(p) => {
                    if panic.is_none() {
                        panic = Some(p);
                    }
                }
            }
        }
        if let Some(p) = panic {
            resume_unwind(p);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            self.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_submitted_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.parallel_map((0..100).collect(), |x: usize| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.parallel_map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn minimum_one_worker() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.parallel_map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn with_threads_zero_is_auto() {
        let pool = ThreadPool::with_threads(0);
        assert!(pool.size() >= 1);
        assert_eq!(ThreadPool::with_threads(3).size(), 3);
    }

    #[test]
    fn panicking_submit_job_does_not_kill_workers() {
        let pool = ThreadPool::new(2);
        for _ in 0..4 {
            pool.submit(|| panic!("boom"));
        }
        // All workers must still be alive and processing.
        let out = pool.parallel_map(vec![1, 2, 3, 4], |x| x * 10);
        assert_eq!(out, vec![10, 20, 30, 40]);
    }

    #[test]
    fn parallel_map_propagates_panic() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_map(vec![0, 1, 2, 3], |x| {
                if x == 2 {
                    panic!("item failed");
                }
                x
            })
        }));
        assert!(caught.is_err(), "panic must surface at the caller");
        // The pool survives and later calls work.
        let out = pool.parallel_map(vec![5, 6], |x| x + 1);
        assert_eq!(out, vec![6, 7]);
    }

    #[test]
    fn scope_run_jobs_borrow_stack_mutably() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0u32; 8];
        let mut jobs: Vec<ScopedJob> = Vec::new();
        for (ci, chunk) in data.chunks_mut(2).enumerate() {
            jobs.push(Box::new(move || {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (ci * 2 + i) as u32;
                }
            }));
        }
        pool.scope_run(jobs);
        assert_eq!(data, (0..8).collect::<Vec<u32>>());
    }

    #[test]
    fn scope_run_empty_and_single() {
        let pool = ThreadPool::new(2);
        pool.scope_run(Vec::new());
        let mut hit = false;
        pool.scope_run(vec![Box::new(|| hit = true) as ScopedJob]);
        assert!(hit);
    }

    #[test]
    fn scope_run_propagates_panic_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<ScopedJob> = vec![Box::new(|| {}), Box::new(|| panic!("scoped boom"))];
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| pool.scope_run(jobs)));
        assert!(caught.is_err(), "panic must surface at the caller");
        let out = pool.parallel_map(vec![1, 2], |x| x + 1);
        assert_eq!(out, vec![2, 3]);
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        let pool = Arc::new(ThreadPool::new(2));
        let mut joins = Vec::new();
        for t in 0..4 {
            let p = Arc::clone(&pool);
            joins.push(thread::spawn(move || {
                p.parallel_map(vec![t; 8], |x: usize| x * 2).iter().sum::<usize>()
            }));
        }
        for (t, j) in joins.into_iter().enumerate() {
            assert_eq!(j.join().unwrap(), t * 2 * 8);
        }
    }
}
