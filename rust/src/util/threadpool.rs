//! Fixed-size worker thread pool (offline stand-in for `rayon`/`tokio`).
//!
//! Drives the coordinator's request execution and parallel parameter
//! sweeps. Scoped `parallel_map` keeps the API simple and safe.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming a shared queue.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (minimum 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                thread::Builder::new()
                    .name(format!("codegemm-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx, handles, size }
    }

    /// Pool sized to available parallelism.
    pub fn default_size() -> ThreadPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget job submission.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.send(Msg::Run(Box::new(job))).expect("pool alive");
    }

    /// Apply `f` to each item, preserving order, using the pool.
    pub fn parallel_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_submitted_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.parallel_map((0..100).collect(), |x: usize| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.parallel_map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn minimum_one_worker() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.parallel_map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
