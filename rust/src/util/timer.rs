//! Wall-clock timing helpers for the bench harness and the coordinator's
//! metrics. `Instant`-based; monotonic.

use std::time::Instant;

/// A simple stopwatch.
#[derive(Clone, Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

/// Accumulating phase timer: attribute wall time to named phases. Used by
/// the CodeGEMM engine to reproduce the paper's Table 6 build/read split.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimer {
    phases: Vec<(String, f64)>,
}

impl PhaseTimer {
    pub fn new() -> PhaseTimer {
        PhaseTimer::default()
    }

    pub fn add(&mut self, phase: &str, seconds: f64) {
        if let Some(e) = self.phases.iter_mut().find(|(n, _)| n == phase) {
            e.1 += seconds;
        } else {
            self.phases.push((phase.to_string(), seconds));
        }
    }

    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let (out, s) = time(f);
        self.add(phase, s);
        out
    }

    pub fn total(&self) -> f64 {
        self.phases.iter().map(|(_, s)| s).sum()
    }

    pub fn seconds(&self, phase: &str) -> f64 {
        self.phases.iter().find(|(n, _)| n == phase).map(|(_, s)| *s).unwrap_or(0.0)
    }

    /// Fraction of total time spent in `phase` (0 if no time recorded).
    pub fn share(&self, phase: &str) -> f64 {
        let t = self.total();
        if t == 0.0 {
            0.0
        } else {
            self.seconds(phase) / t
        }
    }

    pub fn clear(&mut self) {
        self.phases.clear();
    }

    pub fn phases(&self) -> &[(String, f64)] {
        &self.phases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn time_returns_result() {
        let (x, s) = time(|| 41 + 1);
        assert_eq!(x, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut p = PhaseTimer::new();
        p.add("build", 1.0);
        p.add("read", 3.0);
        p.add("build", 1.0);
        assert_eq!(p.seconds("build"), 2.0);
        assert_eq!(p.total(), 5.0);
        assert!((p.share("build") - 0.4).abs() < 1e-12);
        assert_eq!(p.share("missing"), 0.0);
    }

    #[test]
    fn phase_timer_time_closure() {
        let mut p = PhaseTimer::new();
        let v = p.time("work", || 7);
        assert_eq!(v, 7);
        assert!(p.seconds("work") >= 0.0);
        p.clear();
        assert_eq!(p.total(), 0.0);
    }
}
