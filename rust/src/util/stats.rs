//! Summary statistics, error metrics and tiny linear algebra helpers used
//! across the bench harness, the quantizer and the evaluation code.

/// Arithmetic mean. Returns 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `p` in `[0, 100]`. Total: an empty
/// slice reports 0 (the same convention as [`mean`]), so metric paths
/// never have to special-case "no samples yet".
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of an unsorted slice (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&v, 50.0)
}

/// Relative L2 error ‖a−b‖ / ‖b‖ (b is the reference).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0f64;
    let mut den = 0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        num += d * d;
        den += (*y as f64) * (*y as f64);
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

/// Max absolute difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Mean squared error.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64
}

/// Frobenius norm of a flat matrix.
pub fn fro_norm(a: &[f32]) -> f64 {
    a.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
}

/// Dot product (f32 inputs, f64 accumulation — matches the engines).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Softmax in place (numerically stable).
pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
}

/// Log-sum-exp (stable).
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if max.is_infinite() {
        return max;
    }
    let s: f32 = xs.iter().map(|x| (x - max).exp()).sum();
    max + s.ln()
}

/// Summary of a latency sample set.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// The all-zero summary of an empty sample set. `Summary::of(&[])`
    /// returns this, so callers never hand-roll a zeroed struct.
    pub fn empty() -> Summary {
        Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, p50: 0.0, p95: 0.0, p99: 0.0, max: 0.0 }
    }

    /// Summarize a sample set. Total: empty input yields
    /// [`Summary::empty`] instead of panicking.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::empty();
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: v.len(),
            mean: mean(&v),
            std: std_dev(&v),
            min: v[0],
            p50: percentile(&v, 50.0),
            p95: percentile(&v, 95.0),
            p99: percentile(&v, 99.0),
            max: *v.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), 5.0);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn empty_inputs_are_total() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.p99, 0.0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn rel_l2_basics() {
        assert_eq!(rel_l2(&[1.0, 0.0], &[1.0, 0.0]), 0.0);
        let e = rel_l2(&[1.1, 0.0], &[1.0, 0.0]);
        assert!((e - 0.1).abs() < 1e-6);
        assert_eq!(rel_l2(&[0.0], &[0.0]), 0.0);
        assert!(rel_l2(&[1.0], &[0.0]).is_infinite());
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = [1.0, 2.0, 3.0, -100.0];
        softmax_inplace(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut xs = [1000.0, 1000.0];
        softmax_inplace(&mut xs);
        assert!((xs[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn lse_matches_naive_for_small() {
        let xs = [0.1f32, 0.2, 0.3];
        let naive = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((log_sum_exp(&xs) - naive).abs() < 1e-6);
    }

    #[test]
    fn summary_fields_consistent() {
        let s = Summary::of(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.mean, 3.0);
    }

    #[test]
    fn mse_and_fro() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 4.0]), 2.0);
        assert!((fro_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
