//! CGTF tensor-container file I/O.
//!
//! `weights.bin` interchange format between the Python compile path
//! (`python/compile/export.py`) and the Rust runtime. Layout:
//!
//! ```text
//! [ 8 bytes magic "CGTF0001" ]
//! [ u64 LE: header JSON length ]
//! [ header JSON: {"tensors": [{name, dtype, shape, offset, nbytes}, ...]} ]
//! [ raw little-endian tensor data, offsets relative to data start ]
//! ```
//!
//! Supported dtypes: `f32`, `i32`, `u8`, `u16`. All multi-byte values are
//! little-endian (both sides are x86-64/LE here; the reader still goes
//! through explicit `from_le_bytes` so big-endian hosts would work).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CGTF0001";

/// Element type of a stored tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
    U16,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
            DType::U16 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U8 => "u8",
            DType::U16 => "u16",
        }
    }

    pub fn from_name(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" | "float32" => DType::F32,
            "i32" | "int32" => DType::I32,
            "u8" | "uint8" => DType::U8,
            "u16" | "uint16" => DType::U16,
            other => bail!("unsupported dtype '{other}'"),
        })
    }
}

/// Typed tensor payload.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
    U16(Vec<u16>),
}

impl TensorData {
    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
            TensorData::U8(_) => DType::U8,
            TensorData::U16(_) => DType::U16,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U8(v) => v.len(),
            TensorData::U16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorData::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            TensorData::U8(v) => Ok(v),
            other => bail!("expected u8 tensor, got {:?}", other.dtype()),
        }
    }

    fn to_le_bytes(&self) -> Vec<u8> {
        match self {
            TensorData::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            TensorData::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            TensorData::U8(v) => v.clone(),
            TensorData::U16(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        }
    }

    fn from_le_bytes(dtype: DType, bytes: &[u8]) -> Result<TensorData> {
        if bytes.len() % dtype.size() != 0 {
            bail!("byte length {} not divisible by element size {}", bytes.len(), dtype.size());
        }
        Ok(match dtype {
            DType::F32 => TensorData::F32(
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            DType::I32 => TensorData::I32(
                bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            DType::U8 => TensorData::U8(bytes.to_vec()),
            DType::U16 => TensorData::U16(
                bytes.chunks_exact(2).map(|c| u16::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
        })
    }
}

/// A named, shaped tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(name: &str, shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        Tensor { name: name.into(), shape, data: TensorData::F32(data) }
    }

    pub fn u8(name: &str, shape: Vec<usize>, data: Vec<u8>) -> Tensor {
        Tensor { name: name.into(), shape, data: TensorData::U8(data) }
    }

    pub fn i32(name: &str, shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        Tensor { name: name.into(), shape, data: TensorData::I32(data) }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn validate(&self) -> Result<()> {
        if self.numel() != self.data.len() {
            bail!(
                "tensor '{}': shape {:?} (numel {}) != data len {}",
                self.name,
                self.shape,
                self.numel(),
                self.data.len()
            );
        }
        Ok(())
    }
}

/// An ordered collection of named tensors.
#[derive(Clone, Debug, Default)]
pub struct TensorFile {
    pub tensors: Vec<Tensor>,
}

impl TensorFile {
    pub fn new() -> TensorFile {
        TensorFile::default()
    }

    pub fn push(&mut self, t: Tensor) {
        self.tensors.push(t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow!("tensor '{name}' not found (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.iter().map(|t| t.name.as_str()).collect()
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut entries = Vec::new();
        let mut data = Vec::new();
        let mut seen = BTreeMap::new();
        for t in &self.tensors {
            t.validate()?;
            if seen.insert(t.name.clone(), ()).is_some() {
                bail!("duplicate tensor name '{}'", t.name);
            }
            let bytes = t.data.to_le_bytes();
            entries.push(Json::obj(vec![
                ("name", Json::Str(t.name.clone())),
                ("dtype", Json::Str(t.data.dtype().name().into())),
                ("shape", Json::Arr(t.shape.iter().map(|&s| Json::from(s)).collect())),
                ("offset", Json::from(data.len())),
                ("nbytes", Json::from(bytes.len())),
            ]));
            data.extend_from_slice(&bytes);
        }
        let header = Json::obj(vec![("tensors", Json::Arr(entries))]).to_string_compact();
        let mut out = Vec::with_capacity(16 + header.len() + data.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(header.len() as u64).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&data);
        Ok(out)
    }

    /// Parse from bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<TensorFile> {
        if bytes.len() < 16 || &bytes[..8] != MAGIC {
            bail!("not a CGTF file (bad magic)");
        }
        let hlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let header_end = 16 + hlen;
        if bytes.len() < header_end {
            bail!("truncated header");
        }
        let header = Json::parse(std::str::from_utf8(&bytes[16..header_end])?)?;
        let data = &bytes[header_end..];
        let mut tf = TensorFile::new();
        for e in header.req_arr("tensors")? {
            let name = e.req_str("name")?.to_string();
            let dtype = DType::from_name(e.req_str("dtype")?)?;
            let shape = e
                .get("shape")
                .ok_or_else(|| anyhow!("missing shape"))?
                .usize_vec()?;
            let offset = e.req_usize("offset")?;
            let nbytes = e.req_usize("nbytes")?;
            if offset + nbytes > data.len() {
                bail!("tensor '{name}' extends past end of data section");
            }
            let td = TensorData::from_le_bytes(dtype, &data[offset..offset + nbytes])?;
            let t = Tensor { name, shape, data: td };
            t.validate()?;
            tf.push(t);
        }
        Ok(tf)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let bytes = self.to_bytes()?;
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(&bytes)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TensorFile> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        TensorFile::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TensorFile {
        let mut tf = TensorFile::new();
        tf.push(Tensor::f32("w", vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.0]));
        tf.push(Tensor::u8("codes", vec![4], vec![0, 255, 7, 8]));
        tf.push(Tensor::i32("shape_info", vec![2], vec![-1, 1024]));
        tf
    }

    #[test]
    fn roundtrip_bytes() {
        let tf = sample();
        let bytes = tf.to_bytes().unwrap();
        let back = TensorFile::from_bytes(&bytes).unwrap();
        assert_eq!(back.tensors, tf.tensors);
    }

    #[test]
    fn roundtrip_file() {
        let tf = sample();
        let path = std::env::temp_dir().join("cgtf_test.bin");
        tf.save(&path).unwrap();
        let back = TensorFile::load(&path).unwrap();
        assert_eq!(back.tensors, tf.tensors);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn get_by_name() {
        let tf = sample();
        assert_eq!(tf.get("codes").unwrap().data.as_u8().unwrap(), &[0, 255, 7, 8]);
        assert!(tf.get("nope").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(TensorFile::from_bytes(b"XXXX00010000000000000000").is_err());
    }

    #[test]
    fn rejects_shape_mismatch() {
        let mut tf = TensorFile::new();
        tf.push(Tensor::f32("bad", vec![3], vec![1.0]));
        assert!(tf.to_bytes().is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let mut tf = TensorFile::new();
        tf.push(Tensor::f32("a", vec![1], vec![1.0]));
        tf.push(Tensor::f32("a", vec![1], vec![2.0]));
        assert!(tf.to_bytes().is_err());
    }

    #[test]
    fn rejects_truncated_data() {
        let tf = sample();
        let mut bytes = tf.to_bytes().unwrap();
        bytes.truncate(bytes.len() - 4);
        assert!(TensorFile::from_bytes(&bytes).is_err());
    }

    #[test]
    fn dtype_roundtrip_names() {
        for d in [DType::F32, DType::I32, DType::U8, DType::U16] {
            assert_eq!(DType::from_name(d.name()).unwrap(), d);
        }
        assert!(DType::from_name("f64").is_err());
    }
}
