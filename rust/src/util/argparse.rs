//! Tiny declarative CLI argument parser (offline stand-in for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands, typed accessors with defaults, and auto-generated help.

use std::collections::BTreeMap;

/// Specification of one option.
#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// A declarative command parser.
#[derive(Clone, Debug, Default)]
pub struct Command {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>, // (name, help)
}

/// Parse results.
#[derive(Clone, Debug, Default)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pos: Vec<String>,
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Command { name: name.into(), about: about.into(), ..Default::default() }
    }

    /// Boolean flag (`--name`).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec { name: name.into(), help: help.into(), takes_value: false, default: None });
        self
    }

    /// Valued option (`--name VALUE`), optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            default: default.map(str::to_string),
        });
        self
    }

    /// Positional argument (collected in order).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.into(), help.into()));
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        if !self.opts.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        s.push('\n');
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let head = if o.takes_value {
                    format!("--{} <VAL>", o.name)
                } else {
                    format!("--{}", o.name)
                };
                let def = o.default.as_deref().map(|d| format!(" [default: {d}]")).unwrap_or_default();
                s.push_str(&format!("  {head:24} {}{def}\n", o.help));
            }
        }
        s
    }

    /// Parse a raw argv slice (not including the program/subcommand name).
    pub fn parse(&self, args: &[String]) -> anyhow::Result<Matches> {
        let mut m = Matches::default();
        for spec in &self.opts {
            if let Some(d) = &spec.default {
                m.values.insert(spec.name.clone(), d.clone());
            }
            if !spec.takes_value {
                m.flags.insert(spec.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                anyhow::bail!("{}", self.help());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n\n{}", self.help()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{key} requires a value"))?
                        }
                    };
                    m.values.insert(key, val);
                } else {
                    if inline_val.is_some() {
                        anyhow::bail!("--{key} does not take a value");
                    }
                    m.flags.insert(key, true);
                }
            } else {
                m.pos.push(a.clone());
            }
            i += 1;
        }
        if m.pos.len() < self.positionals.len() {
            anyhow::bail!(
                "missing positional <{}>\n\n{}",
                self.positionals[m.pos.len()].0,
                self.help()
            );
        }
        Ok(m)
    }
}

impl Matches {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn str(&self, name: &str) -> anyhow::Result<&str> {
        self.get(name).ok_or_else(|| anyhow::anyhow!("missing --{name}"))
    }

    pub fn usize(&self, name: &str) -> anyhow::Result<usize> {
        Ok(self.str(name)?.parse::<usize>().map_err(|e| anyhow::anyhow!("--{name}: {e}"))?)
    }

    pub fn i64(&self, name: &str) -> anyhow::Result<i64> {
        Ok(self.str(name)?.parse::<i64>().map_err(|e| anyhow::anyhow!("--{name}: {e}"))?)
    }

    pub fn f64(&self, name: &str) -> anyhow::Result<f64> {
        Ok(self.str(name)?.parse::<f64>().map_err(|e| anyhow::anyhow!("--{name}: {e}"))?)
    }

    /// Comma-separated usize list, e.g. `--batch-sizes 1,4,8`.
    pub fn usize_list(&self, name: &str) -> anyhow::Result<Vec<usize>> {
        self.str(name)?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow::anyhow!("--{name}: {e}")))
            .collect()
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.pos.get(idx).map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("t", "test cmd")
            .flag("verbose", "talk more")
            .opt("n", Some("4"), "count")
            .opt("name", None, "label")
            .positional("input", "input file")
    }

    #[test]
    fn parses_flags_values_positionals() {
        let m = cmd().parse(&args(&["--verbose", "--n", "9", "file.txt", "--name=x"])).unwrap();
        assert!(m.flag("verbose"));
        assert_eq!(m.usize("n").unwrap(), 9);
        assert_eq!(m.str("name").unwrap(), "x");
        assert_eq!(m.positional(0), Some("file.txt"));
    }

    #[test]
    fn defaults_apply() {
        let m = cmd().parse(&args(&["f"])).unwrap();
        assert_eq!(m.usize("n").unwrap(), 4);
        assert!(!m.flag("verbose"));
        assert!(m.get("name").is_none());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&args(&["--bogus", "f"])).is_err());
    }

    #[test]
    fn missing_positional_errors() {
        assert!(cmd().parse(&args(&[])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cmd().parse(&args(&["f", "--n"])).is_err());
    }

    #[test]
    fn usize_list_parses() {
        let c = Command::new("t", "").opt("bs", Some("1,4,8"), "");
        let m = c.parse(&args(&[])).unwrap();
        assert_eq!(m.usize_list("bs").unwrap(), vec![1, 4, 8]);
    }

    #[test]
    fn help_contains_options() {
        let h = cmd().help();
        assert!(h.contains("--verbose"));
        assert!(h.contains("[default: 4]"));
        assert!(h.contains("<input>"));
    }
}
