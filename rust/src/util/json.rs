//! Minimal JSON document model, parser and pretty-printer.
//!
//! Replaces `serde_json` in the offline environment. Used for the config
//! system, the artifact manifest written by `python/compile/aot.py`, and
//! machine-readable bench output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization
/// is deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 { Some(x as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers that produce good error messages.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    pub fn req_i64(&self, key: &str) -> anyhow::Result<i64> {
        self.get(key)
            .and_then(|v| v.as_i64())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    /// Optional-field helper: `default` when the key is absent, an error
    /// when present but not a non-negative integer (so typos fail loudly
    /// instead of silently falling back).
    pub fn opt_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.as_usize().ok_or_else(|| anyhow::anyhow!("invalid integer field '{key}'"))
            }
        }
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field '{key}'"))
    }

    /// Array of usize convenience (shapes etc.).
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("expected non-negative int")))
            .collect()
    }

    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!("expected '{}' at byte {}", c as char, self.pos)
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => anyhow::bail!("unexpected char '{}' at byte {}", c as char, self.pos),
            None => anyhow::bail!("unexpected end of input"),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // Surrogate pairs: parse trailing low surrogate.
                            let c = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos + 1..self.pos + 3) == Some(b"\\u") {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 3..self.pos + 7)
                                        .ok_or_else(|| anyhow::anyhow!("bad surrogate"))?;
                                    let low = u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    anyhow::bail!("lone high surrogate");
                                }
                            } else {
                                code
                            };
                            s.push(char::from_u32(c).ok_or_else(|| anyhow::anyhow!("bad codepoint"))?);
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(j.req_arr("a").unwrap().len(), 3);
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"k":[1,2.5,true,null,"s\n"],"z":{"q":-3}}"#;
        let j = Json::parse(src).unwrap();
        for text in [j.to_string_compact(), j.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(42.5).to_string_compact(), "42.5");
    }

    #[test]
    fn req_helpers_error_messages() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(j.req_str("a").is_err());
        assert!(j.req_usize("missing").is_err());
        assert_eq!(j.req_usize("a").unwrap(), 1);
    }

    #[test]
    fn usize_vec_shapes() {
        let j = Json::parse("[2, 3, 4]").unwrap();
        assert_eq!(j.usize_vec().unwrap(), vec![2, 3, 4]);
        assert!(Json::parse("[1, -2]").unwrap().usize_vec().is_err());
    }
}
