//! Substrate utilities built from scratch (the build environment is fully
//! offline, so the usual ecosystem crates — `rand`, `serde`, `clap`,
//! `rayon`, `criterion`, `proptest` — are replaced by small, tested,
//! purpose-built implementations).

pub mod argparse;
pub mod f16;
pub mod json;
pub mod npy;
pub mod proptest;
pub mod prng;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod timer;
